//! Model bundles & signatures — the serialized contract between the
//! Python frontend, on-disk model directories, and the serving stack.
//!
//! Three pieces:
//!
//! * **GraphDef** ([`graph_to_json`] / [`graph_from_json`]) — a JSON
//!   (de)serialization of [`Graph`] covering every [`OpKind`] variant:
//!   explicit device annotations, small constants embedded inline (with
//!   f32-exact number round-tripping, see [`crate::util::json`]), and
//!   named weight-artifact references (`ConvFixedF32` / `FcFixed` resolve
//!   their weights from the session's artifact store by name, so the
//!   GraphDef carries only the names).
//! * **[`Signature`]** — named input/output endpoints (name → graph node,
//!   shape, dtype) — and **[`ModelBundle`]**, the directory format
//!   (`model.json` = GraphDef + signatures + artifact refs) with
//!   [`ModelBundle::save`] / [`ModelBundle::load`]. The Python frontend
//!   writes the identical format (`python -m compile.export`), closing the
//!   Python → FPGA loop without a specialized toolchain.
//! * **[`Model`]** — a facade over [`Session`] that resolves feeds and
//!   fetches by *endpoint name* instead of raw node names:
//!   `model.invoke("serve", &[("x", t)])`. Mis-shaped feeds fail up front
//!   with an error naming the endpoint and the expected vs. fed meta,
//!   instead of a NodeId-level failure deep in the executor. Each
//!   signature maps to one `(feeds, fetches)` shape, so the session's
//!   plan cache holds exactly one compiled plan per signature.
//!
//! The serving layer ([`crate::serve`]) hosts any number of bundles in a
//! single session, batching each along dimension 0 of its input endpoint.

use crate::hsa::agent::DeviceType;
use crate::hsa::error::{HsaError, Result};
use crate::tf::dtype::DType;
use crate::tf::graph::{Graph, NodeId, OpKind};
use crate::tf::session::{PendingRun, Session, SessionOptions};
use crate::tf::tensor::Tensor;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

/// The signature name conventionally used for the serving entry point.
pub const SERVE_SIGNATURE: &str = "serve";

/// Bundle `model.json` format tag and version.
pub const BUNDLE_FORMAT: &str = "tf-fpga-model-bundle";
pub const BUNDLE_VERSION: usize = 1;

fn rt_err(msg: impl Into<String>) -> HsaError {
    HsaError::Runtime(msg.into())
}

// ---------------------------------------------------------------------------
// GraphDef: Graph <-> Json
// ---------------------------------------------------------------------------

fn device_tag(d: DeviceType) -> &'static str {
    match d {
        DeviceType::Cpu => "cpu",
        DeviceType::Fpga => "fpga",
        DeviceType::Gpu => "gpu",
        DeviceType::Dsp => "dsp",
    }
}

fn device_from_tag(s: &str) -> Option<DeviceType> {
    match s {
        "cpu" => Some(DeviceType::Cpu),
        "fpga" => Some(DeviceType::Fpga),
        "gpu" => Some(DeviceType::Gpu),
        "dsp" => Some(DeviceType::Dsp),
        _ => None,
    }
}

fn shape_to_json(shape: &[usize]) -> Json {
    Json::Arr(shape.iter().map(|&d| Json::from_usize(d)).collect())
}

fn shape_from_json(ctx: &str, v: &Json) -> Result<Vec<usize>> {
    v.as_arr()
        .ok_or_else(|| rt_err(format!("{ctx}: expected a shape array")))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| rt_err(format!("{ctx}: bad shape dim {d}"))))
        .collect()
}

fn dtype_from_json(ctx: &str, v: &Json) -> Result<DType> {
    v.as_str()
        .and_then(DType::from_manifest)
        .ok_or_else(|| rt_err(format!("{ctx}: bad dtype {v}")))
}

fn tensor_to_json(t: &Tensor) -> Json {
    let data = match t.dtype() {
        DType::F32 => t
            .as_f32()
            .unwrap()
            .iter()
            .map(|&v| Json::from_f32(v))
            .collect(),
        DType::I16 => t
            .as_i16()
            .unwrap()
            .iter()
            .map(|&v| Json::Num(v as f64))
            .collect(),
        DType::I32 => t
            .as_i32()
            .unwrap()
            .iter()
            .map(|&v| Json::Num(v as f64))
            .collect(),
    };
    let mut m = BTreeMap::new();
    m.insert("shape".to_string(), shape_to_json(t.shape()));
    m.insert("dtype".to_string(), Json::Str(t.dtype().as_manifest().to_string()));
    m.insert("data".to_string(), Json::Arr(data));
    Json::Obj(m)
}

fn tensor_from_json(ctx: &str, v: &Json) -> Result<Tensor> {
    let shape = shape_from_json(ctx, v.get("shape"))?;
    let dtype = dtype_from_json(ctx, v.get("dtype"))?;
    let data = v
        .get("data")
        .as_arr()
        .ok_or_else(|| rt_err(format!("{ctx}: constant missing data array")))?;
    let int_val = |d: &Json, lo: f64, hi: f64| -> Result<f64> {
        let n = d
            .as_f64()
            .ok_or_else(|| rt_err(format!("{ctx}: non-numeric constant element {d}")))?;
        if n.fract() != 0.0 || n < lo || n > hi {
            return Err(rt_err(format!("{ctx}: integer constant element {n} out of range")));
        }
        Ok(n)
    };
    let t = match dtype {
        DType::F32 => {
            let vals = data
                .iter()
                .map(|d| {
                    d.as_f32()
                        .ok_or_else(|| rt_err(format!("{ctx}: non-numeric constant element {d}")))
                })
                .collect::<Result<Vec<f32>>>()?;
            Tensor::from_f32(&shape, vals)?
        }
        DType::I16 => {
            let vals = data
                .iter()
                .map(|d| int_val(d, i16::MIN as f64, i16::MAX as f64).map(|n| n as i16))
                .collect::<Result<Vec<i16>>>()?;
            Tensor::from_i16(&shape, vals)?
        }
        DType::I32 => {
            let vals = data
                .iter()
                .map(|d| int_val(d, i32::MIN as f64, i32::MAX as f64).map(|n| n as i32))
                .collect::<Result<Vec<i32>>>()?;
            Tensor::from_i32(&shape, vals)?
        }
    };
    Ok(t)
}

fn op_to_json(m: &mut BTreeMap<String, Json>, op: &OpKind) {
    let tag = match op {
        OpKind::Placeholder { .. } => "placeholder",
        OpKind::Constant(_) => "constant",
        OpKind::FullyConnected => "fully_connected",
        OpKind::FcBarrier => "fc_barrier",
        OpKind::Conv5x5I16 => "conv5x5_i16",
        OpKind::Conv3x3I16 => "conv3x3_i16",
        OpKind::ConvFixedF32 { .. } => "conv_fixed_f32",
        OpKind::FcFixed { .. } => "fc_fixed",
        OpKind::Conv2dF32 { .. } => "conv2d",
        OpKind::Relu => "relu",
        OpKind::Softmax => "softmax",
        OpKind::MaxPool2 => "maxpool2",
        OpKind::GlobalAvgPool => "global_avgpool",
        OpKind::Concat { .. } => "concat",
        OpKind::Reshape { .. } => "reshape",
        OpKind::Add => "add",
        OpKind::Quantize { .. } => "quantize",
        OpKind::Dequantize { .. } => "dequantize",
        OpKind::MnistCnn => "mnist_cnn",
        OpKind::Custom { .. } => "custom",
    };
    m.insert("op".to_string(), Json::Str(tag.to_string()));
    match op {
        OpKind::Placeholder { shape, dtype } => {
            m.insert("shape".to_string(), shape_to_json(shape));
            m.insert("dtype".to_string(), Json::Str(dtype.as_manifest().to_string()));
        }
        OpKind::Constant(t) => {
            m.insert("tensor".to_string(), tensor_to_json(t));
        }
        OpKind::ConvFixedF32 { weights, filters, cin, kh, kw } => {
            m.insert("weights".to_string(), Json::Str(weights.clone()));
            m.insert("filters".to_string(), Json::from_usize(*filters));
            m.insert("cin".to_string(), Json::from_usize(*cin));
            m.insert("kh".to_string(), Json::from_usize(*kh));
            m.insert("kw".to_string(), Json::from_usize(*kw));
        }
        OpKind::FcFixed { weights_w, weights_b, out_width } => {
            m.insert("weights_w".to_string(), Json::Str(weights_w.clone()));
            m.insert("weights_b".to_string(), Json::Str(weights_b.clone()));
            m.insert("out_width".to_string(), Json::from_usize(*out_width));
        }
        OpKind::Conv2dF32 { pad } => {
            m.insert("pad".to_string(), Json::from_usize(*pad));
        }
        OpKind::Concat { axis } => {
            m.insert("axis".to_string(), Json::from_usize(*axis));
        }
        OpKind::Reshape { shape } => {
            m.insert("shape".to_string(), shape_to_json(shape));
        }
        OpKind::Quantize { frac_bits } | OpKind::Dequantize { frac_bits } => {
            m.insert("frac_bits".to_string(), Json::from_usize(*frac_bits as usize));
        }
        OpKind::Custom { kernel, out_shape, out_dtype } => {
            m.insert("kernel".to_string(), Json::Str(kernel.clone()));
            m.insert("out_shape".to_string(), shape_to_json(out_shape));
            m.insert(
                "out_dtype".to_string(),
                Json::Str(out_dtype.as_manifest().to_string()),
            );
        }
        _ => {}
    }
}

fn op_from_json(name: &str, v: &Json) -> Result<OpKind> {
    let ctx = format!("node '{name}'");
    let tag = v
        .get("op")
        .as_str()
        .ok_or_else(|| rt_err(format!("{ctx}: missing op tag")))?;
    let ufield = |key: &str| -> Result<usize> {
        v.get(key)
            .as_usize()
            .ok_or_else(|| rt_err(format!("{ctx}: missing/bad field '{key}'")))
    };
    let sfield = |key: &str| -> Result<String> {
        v.get(key)
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| rt_err(format!("{ctx}: missing/bad field '{key}'")))
    };
    Ok(match tag {
        "placeholder" => OpKind::Placeholder {
            shape: shape_from_json(&ctx, v.get("shape"))?,
            dtype: dtype_from_json(&ctx, v.get("dtype"))?,
        },
        "constant" => OpKind::Constant(tensor_from_json(&ctx, v.get("tensor"))?),
        "fully_connected" => OpKind::FullyConnected,
        "fc_barrier" => OpKind::FcBarrier,
        "conv5x5_i16" => OpKind::Conv5x5I16,
        "conv3x3_i16" => OpKind::Conv3x3I16,
        "conv_fixed_f32" => OpKind::ConvFixedF32 {
            weights: sfield("weights")?,
            filters: ufield("filters")?,
            cin: ufield("cin")?,
            kh: ufield("kh")?,
            kw: ufield("kw")?,
        },
        "fc_fixed" => OpKind::FcFixed {
            weights_w: sfield("weights_w")?,
            weights_b: sfield("weights_b")?,
            out_width: ufield("out_width")?,
        },
        "conv2d" => OpKind::Conv2dF32 { pad: ufield("pad")? },
        "relu" => OpKind::Relu,
        "softmax" => OpKind::Softmax,
        "maxpool2" => OpKind::MaxPool2,
        "global_avgpool" => OpKind::GlobalAvgPool,
        "concat" => OpKind::Concat { axis: ufield("axis")? },
        "reshape" => OpKind::Reshape { shape: shape_from_json(&ctx, v.get("shape"))? },
        "add" => OpKind::Add,
        "quantize" => OpKind::Quantize { frac_bits: ufield("frac_bits")? as u32 },
        "dequantize" => OpKind::Dequantize { frac_bits: ufield("frac_bits")? as u32 },
        "mnist_cnn" => OpKind::MnistCnn,
        "custom" => OpKind::Custom {
            kernel: sfield("kernel")?,
            out_shape: shape_from_json(&ctx, v.get("out_shape"))?,
            out_dtype: dtype_from_json(&ctx, v.get("out_dtype"))?,
        },
        other => return Err(rt_err(format!("{ctx}: unknown op tag '{other}'"))),
    })
}

/// Serialize a graph to its GraphDef JSON form. Nodes are written in
/// insertion (= topological) order; inputs are referenced by node name,
/// so the representation is stable under NodeId renumbering.
pub fn graph_to_json(graph: &Graph) -> Json {
    let mut nodes = Vec::with_capacity(graph.len());
    for node in graph.nodes() {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(node.name.clone()));
        op_to_json(&mut m, &node.op);
        if !node.inputs.is_empty() {
            m.insert(
                "inputs".to_string(),
                Json::Arr(
                    node.inputs
                        .iter()
                        .map(|&i| Json::Str(graph.node(i).name.clone()))
                        .collect(),
                ),
            );
        }
        if let Some(d) = node.device {
            m.insert("device".to_string(), Json::Str(device_tag(d).to_string()));
        }
        nodes.push(Json::Obj(m));
    }
    let mut g = BTreeMap::new();
    g.insert("nodes".to_string(), Json::Arr(nodes));
    Json::Obj(g)
}

/// Parse a GraphDef JSON document back into an (unfinalized) [`Graph`].
/// Node order in the document must be topological (inputs before
/// consumers), which [`graph_to_json`] guarantees.
pub fn graph_from_json(v: &Json) -> Result<Graph> {
    let nodes = v
        .get("nodes")
        .as_arr()
        .ok_or_else(|| rt_err("graphdef: missing nodes array"))?;
    let mut g = Graph::new();
    for (idx, nv) in nodes.iter().enumerate() {
        let name = nv
            .get("name")
            .as_str()
            .ok_or_else(|| rt_err(format!("graphdef node {idx}: missing name")))?
            .to_string();
        let op = op_from_json(&name, nv)?;
        let mut input_ids: Vec<NodeId> = Vec::new();
        match nv.get("inputs") {
            Json::Null => {} // absent = no inputs
            inputs => {
                let arr = inputs.as_arr().ok_or_else(|| {
                    rt_err(format!("node '{name}': inputs must be an array, got {inputs}"))
                })?;
                for s in arr {
                    let input_name = s
                        .as_str()
                        .ok_or_else(|| rt_err(format!("node '{name}': non-string input {s}")))?;
                    input_ids.push(g.by_name(input_name).ok_or_else(|| {
                        rt_err(format!(
                            "node '{name}': input '{input_name}' not defined before use"
                        ))
                    })?);
                }
            }
        }
        let id = g.add(name.clone(), op, &input_ids)?;
        match nv.get("device") {
            Json::Null => {}
            d => {
                let tag = d
                    .as_str()
                    .ok_or_else(|| rt_err(format!("node '{name}': non-string device {d}")))?;
                let dev = device_from_tag(tag).ok_or_else(|| {
                    rt_err(format!("node '{name}': unknown device '{tag}'"))
                })?;
                g.set_device(id, dev);
            }
        }
    }
    Ok(g)
}

// ---------------------------------------------------------------------------
// Signatures
// ---------------------------------------------------------------------------

/// One named I/O endpoint of a signature: the public name, the graph node
/// it binds to, and the tensor meta a caller must provide (inputs) or will
/// receive (outputs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Endpoint {
    pub name: String,
    pub node: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl Endpoint {
    pub fn new(
        name: impl Into<String>,
        node: impl Into<String>,
        shape: &[usize],
        dtype: DType,
    ) -> Endpoint {
        Endpoint { name: name.into(), node: node.into(), shape: shape.to_vec(), dtype }
    }

    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("node".to_string(), Json::Str(self.node.clone()));
        m.insert("shape".to_string(), shape_to_json(&self.shape));
        m.insert("dtype".to_string(), Json::Str(self.dtype.as_manifest().to_string()));
        Json::Obj(m)
    }

    fn from_json(ctx: &str, v: &Json) -> Result<Endpoint> {
        let name = v
            .get("name")
            .as_str()
            .ok_or_else(|| rt_err(format!("{ctx}: endpoint missing name")))?
            .to_string();
        // Absent "node" defaults to the endpoint name; a present but
        // non-string value is malformed, not a default.
        let node = match v.get("node") {
            Json::Null => name.clone(),
            n => n
                .as_str()
                .ok_or_else(|| {
                    rt_err(format!("{ctx} endpoint '{name}': non-string node {n}"))
                })?
                .to_string(),
        };
        Ok(Endpoint {
            shape: shape_from_json(&format!("{ctx} endpoint '{name}'"), v.get("shape"))?,
            dtype: dtype_from_json(&format!("{ctx} endpoint '{name}'"), v.get("dtype"))?,
            name,
            node,
        })
    }
}

/// A named entry point into a model: input and output endpoints with full
/// tensor metas. One signature corresponds to one `(feeds, fetches)` shape
/// and therefore to exactly one cached execution plan in the session.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Signature {
    pub name: String,
    pub inputs: Vec<Endpoint>,
    pub outputs: Vec<Endpoint>,
}

impl Signature {
    pub fn input(&self, name: &str) -> Option<&Endpoint> {
        self.inputs.iter().find(|e| e.name == name)
    }

    pub fn output(&self, name: &str) -> Option<&Endpoint> {
        self.outputs.iter().find(|e| e.name == name)
    }

    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert(
            "inputs".to_string(),
            Json::Arr(self.inputs.iter().map(Endpoint::to_json).collect()),
        );
        m.insert(
            "outputs".to_string(),
            Json::Arr(self.outputs.iter().map(Endpoint::to_json).collect()),
        );
        Json::Obj(m)
    }

    fn from_json(v: &Json) -> Result<Signature> {
        let name = v
            .get("name")
            .as_str()
            .ok_or_else(|| rt_err("signature missing name"))?
            .to_string();
        let ctx = format!("signature '{name}'");
        let parse_eps = |key: &str| -> Result<Vec<Endpoint>> {
            v.get(key)
                .as_arr()
                .ok_or_else(|| rt_err(format!("{ctx}: missing {key} array")))?
                .iter()
                .map(|e| Endpoint::from_json(&ctx, e))
                .collect()
        };
        Ok(Signature { inputs: parse_eps("inputs")?, outputs: parse_eps("outputs")?, name })
    }
}

// ---------------------------------------------------------------------------
// ModelBundle
// ---------------------------------------------------------------------------

/// Signature lookup shared by [`ModelBundle`] and [`Model`]; `owner` is
/// the error-message prefix (e.g. `bundle 'mnist'`).
fn find_signature<'a>(
    signatures: &'a [Signature],
    owner: &str,
    name: &str,
) -> Result<&'a Signature> {
    signatures.iter().find(|s| s.name == name).ok_or_else(|| {
        let known: Vec<&str> = signatures.iter().map(|s| s.name.as_str()).collect();
        rt_err(format!("{owner}: no signature '{name}' (available: {known:?})"))
    })
}

/// Nodes reachable from `roots` through input edges. Shared with the
/// serving layer, which merges only a signature's cone into its session.
pub(crate) fn fetch_cone(graph: &Graph, roots: &[NodeId]) -> Vec<bool> {
    let mut live = vec![false; graph.len()];
    let mut stack: Vec<NodeId> = roots.to_vec();
    while let Some(id) = stack.pop() {
        if live[id.0] {
            continue;
        }
        live[id.0] = true;
        for &i in &graph.node(id).inputs {
            stack.push(i);
        }
    }
    live
}

/// A self-describing model: a finalized graph plus its signatures. On
/// disk, a bundle is a directory holding `model.json` (GraphDef +
/// signatures + artifact refs). Weights are either embedded as `Constant`
/// nodes (fully self-contained) or referenced by artifact name
/// (`ConvFixedF32` / `FcFixed`), resolved by the session's weight bank.
#[derive(Debug, Clone)]
pub struct ModelBundle {
    pub name: String,
    pub graph: Graph,
    pub signatures: Vec<Signature>,
}

impl ModelBundle {
    /// Build and validate a bundle. Finalizes the graph if needed; every
    /// endpoint must name an existing node and match its inferred meta,
    /// and each signature's input endpoints must cover every placeholder
    /// its outputs depend on.
    pub fn new(
        name: impl Into<String>,
        mut graph: Graph,
        signatures: Vec<Signature>,
    ) -> Result<ModelBundle> {
        if !graph.is_finalized() {
            graph.finalize()?;
        }
        let bundle = ModelBundle { name: name.into(), graph, signatures };
        bundle.validate()?;
        Ok(bundle)
    }

    fn validate(&self) -> Result<()> {
        if self.signatures.is_empty() {
            return Err(rt_err(format!("bundle '{}': no signatures", self.name)));
        }
        let mut seen = Vec::new();
        for sig in &self.signatures {
            if seen.contains(&&sig.name) {
                return Err(rt_err(format!(
                    "bundle '{}': duplicate signature '{}'",
                    self.name, sig.name
                )));
            }
            seen.push(&sig.name);
            if sig.outputs.is_empty() {
                return Err(rt_err(format!(
                    "bundle '{}': signature '{}' has no outputs",
                    self.name, sig.name
                )));
            }
            let ctx = format!("bundle '{}' signature '{}'", self.name, sig.name);
            let check_eps = |eps: &[Endpoint], role: &str| -> Result<Vec<NodeId>> {
                let mut names = Vec::new();
                let mut ids = Vec::new();
                for ep in eps {
                    if names.contains(&&ep.name) {
                        return Err(rt_err(format!(
                            "{ctx}: duplicate {role} endpoint '{}'",
                            ep.name
                        )));
                    }
                    names.push(&ep.name);
                    let id = self.graph.by_name(&ep.node).ok_or_else(|| {
                        rt_err(format!(
                            "{ctx}: {role} endpoint '{}' names unknown node '{}'",
                            ep.name, ep.node
                        ))
                    })?;
                    let node = self.graph.node(id);
                    if node.out_shape != ep.shape || node.out_dtype != ep.dtype {
                        return Err(rt_err(format!(
                            "{ctx}: {role} endpoint '{}' declares {:?} {} but node '{}' \
                             produces {:?} {}",
                            ep.name, ep.shape, ep.dtype, ep.node, node.out_shape,
                            node.out_dtype
                        )));
                    }
                    ids.push(id);
                }
                Ok(ids)
            };
            let input_ids = check_eps(&sig.inputs, "input")?;
            let output_ids = check_eps(&sig.outputs, "output")?;
            for (ep, &id) in sig.inputs.iter().zip(&input_ids) {
                if !matches!(self.graph.node(id).op, OpKind::Placeholder { .. }) {
                    return Err(rt_err(format!(
                        "{ctx}: input endpoint '{}' must bind a placeholder, '{}' is not",
                        ep.name, ep.node
                    )));
                }
            }
            // Every placeholder the outputs depend on must be fed through
            // some input endpoint, or the signature can never run.
            let live = fetch_cone(&self.graph, &output_ids);
            for node in self.graph.nodes() {
                if live[node.id.0]
                    && matches!(node.op, OpKind::Placeholder { .. })
                    && !input_ids.contains(&node.id)
                {
                    return Err(rt_err(format!(
                        "{ctx}: outputs depend on placeholder '{}' which no input \
                         endpoint covers",
                        node.name
                    )));
                }
            }
        }
        // Embedded weights must be serializable: JSON has no NaN/Infinity,
        // so a non-finite constant would save as `null` and never load
        // back. Reject it here, at construction, with the node named.
        for node in self.graph.nodes() {
            if let OpKind::Constant(t) = &node.op {
                if let Ok(vals) = t.as_f32() {
                    if let Some(bad) = vals.iter().find(|v| !v.is_finite()) {
                        return Err(rt_err(format!(
                            "bundle '{}': constant '{}' holds non-finite value {bad}, \
                             which JSON cannot represent",
                            self.name, node.name
                        )));
                    }
                }
            }
        }
        Ok(())
    }

    pub fn signature(&self, name: &str) -> Result<&Signature> {
        find_signature(&self.signatures, &format!("bundle '{}'", self.name), name)
    }

    /// Named weight artifacts the graph references (`ConvFixedF32` /
    /// `FcFixed` weights), deduplicated and sorted. Purely informational:
    /// the session resolves them from its weight bank / artifact store.
    pub fn artifact_refs(&self) -> Vec<String> {
        let mut refs = Vec::new();
        for node in self.graph.nodes() {
            match &node.op {
                OpKind::ConvFixedF32 { weights, .. } => refs.push(weights.clone()),
                OpKind::FcFixed { weights_w, weights_b, .. } => {
                    refs.push(weights_w.clone());
                    refs.push(weights_b.clone());
                }
                _ => {}
            }
        }
        refs.sort();
        refs.dedup();
        refs
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("format".to_string(), Json::Str(BUNDLE_FORMAT.to_string()));
        m.insert("version".to_string(), Json::from_usize(BUNDLE_VERSION));
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("graph".to_string(), graph_to_json(&self.graph));
        m.insert(
            "signatures".to_string(),
            Json::Arr(self.signatures.iter().map(Signature::to_json).collect()),
        );
        m.insert(
            "artifacts".to_string(),
            Json::Arr(self.artifact_refs().into_iter().map(Json::Str).collect()),
        );
        Json::Obj(m)
    }

    /// Parse a bundle document. `fallback_name` is used when the document
    /// omits `name` (e.g. hand-written bundles), typically the directory
    /// name.
    pub fn from_json(v: &Json, fallback_name: &str) -> Result<ModelBundle> {
        match v.get("format").as_str() {
            Some(BUNDLE_FORMAT) => {}
            other => {
                return Err(rt_err(format!(
                    "not a model bundle: format {other:?}, expected '{BUNDLE_FORMAT}'"
                )))
            }
        }
        match v.get("version").as_usize() {
            Some(BUNDLE_VERSION) => {}
            other => {
                return Err(rt_err(format!(
                    "unsupported bundle version {other:?} (this runtime reads {BUNDLE_VERSION})"
                )))
            }
        }
        let name = v.get("name").as_str().unwrap_or(fallback_name).to_string();
        let graph = graph_from_json(v.get("graph"))?;
        let signatures = v
            .get("signatures")
            .as_arr()
            .ok_or_else(|| rt_err(format!("bundle '{name}': missing signatures array")))?
            .iter()
            .map(Signature::from_json)
            .collect::<Result<Vec<_>>>()?;
        ModelBundle::new(name, graph, signatures)
    }

    /// Write `<dir>/model.json` (pretty-printed, creating `dir`).
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)
            .map_err(|e| rt_err(format!("create {}: {e}", dir.display())))?;
        let path = dir.join("model.json");
        std::fs::write(&path, self.to_json().pretty())
            .map_err(|e| rt_err(format!("write {}: {e}", path.display())))
    }

    /// Load `<dir>/model.json`, validating the graph and signatures.
    pub fn load(dir: impl AsRef<Path>) -> Result<ModelBundle> {
        let dir = dir.as_ref();
        let path = dir.join("model.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| rt_err(format!("cannot read {}: {e}", path.display())))?;
        let doc = Json::parse(&text)
            .map_err(|e| rt_err(format!("{}: {e}", path.display())))?;
        let fallback = dir
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| "model".to_string());
        ModelBundle::from_json(&doc, &fallback)
    }

    // ---- built-in demo bundles (also written by `tf-fpga export-demo`) ----

    /// The whole-model MNIST CNN (one `mnist_cnn` dispatch per batch),
    /// batched along dim 0 — the canonical serving demo.
    pub fn mnist_demo(max_batch: usize) -> ModelBundle {
        let mut g = Graph::new();
        let x = g
            .placeholder("x", &[max_batch, 1, 28, 28], DType::F32)
            .expect("fresh graph");
        g.add("logits", OpKind::MnistCnn, &[x]).expect("fresh graph");
        let sig = Signature {
            name: SERVE_SIGNATURE.to_string(),
            inputs: vec![Endpoint::new("x", "x", &[max_batch, 1, 28, 28], DType::F32)],
            outputs: vec![Endpoint::new("logits", "logits", &[max_batch, 10], DType::F32)],
        };
        ModelBundle::new("mnist", g, vec![sig]).expect("demo bundle is valid")
    }

    /// The MNIST CNN as individual layers with *named weight-artifact
    /// references* (conv/fc weights resolved from the session's weight
    /// bank). Rank-3 convs process one image, so this bundle serves with
    /// batch 1 and is mainly a [`Model::invoke`] showcase.
    pub fn mnist_layers_demo() -> ModelBundle {
        let mut g = Graph::new();
        let x = g.placeholder("x", &[1, 28, 28], DType::F32).expect("fresh graph");
        let c1 = g
            .add(
                "conv1",
                OpKind::ConvFixedF32 {
                    weights: "cnn/conv1".into(),
                    filters: 2,
                    cin: 1,
                    kh: 3,
                    kw: 3,
                },
                &[x],
            )
            .unwrap();
        let r1 = g.add("relu1", OpKind::Relu, &[c1]).unwrap();
        let p1 = g.add("pool1", OpKind::MaxPool2, &[r1]).unwrap();
        let c2 = g
            .add(
                "conv2",
                OpKind::ConvFixedF32 {
                    weights: "cnn/conv2".into(),
                    filters: 4,
                    cin: 2,
                    kh: 5,
                    kw: 5,
                },
                &[p1],
            )
            .unwrap();
        let r2 = g.add("relu2", OpKind::Relu, &[c2]).unwrap();
        let p2 = g.add("pool2", OpKind::MaxPool2, &[r2]).unwrap();
        let flat = g
            .add("flat", OpKind::Reshape { shape: vec![1, 64] }, &[p2])
            .unwrap();
        let fc1 = g
            .add(
                "fc1",
                OpKind::FcFixed {
                    weights_w: "cnn/fc1_w".into(),
                    weights_b: "cnn/fc1_b".into(),
                    out_width: 32,
                },
                &[flat],
            )
            .unwrap();
        let r3 = g.add("relu3", OpKind::Relu, &[fc1]).unwrap();
        g.add(
            "logits",
            OpKind::FcFixed {
                weights_w: "cnn/fc2_w".into(),
                weights_b: "cnn/fc2_b".into(),
                out_width: 10,
            },
            &[r3],
        )
        .unwrap();
        let sig = Signature {
            name: SERVE_SIGNATURE.to_string(),
            inputs: vec![Endpoint::new("x", "x", &[1, 28, 28], DType::F32)],
            outputs: vec![Endpoint::new("logits", "logits", &[1, 10], DType::F32)],
        };
        ModelBundle::new("mnist_layers", g, vec![sig]).expect("demo bundle is valid")
    }

    /// A tiny dense model with weights *embedded* as constants in the
    /// GraphDef (fully self-contained, no artifact store needed) and an
    /// input shape unlike MNIST's — exercises arbitrary-shape serving.
    pub fn tiny_fc_demo(batch: usize, in_dim: usize, out_dim: usize) -> ModelBundle {
        let mut rng = crate::util::prng::Rng::new(
            0x7157_FC00 ^ ((in_dim as u64) << 8) ^ (out_dim as u64),
        );
        let mut wv = vec![0f32; in_dim * out_dim];
        rng.fill_f32_normal(&mut wv, 0.0, 0.3);
        let mut bv = vec![0f32; out_dim];
        rng.fill_f32_normal(&mut bv, 0.0, 0.1);
        let mut g = Graph::new();
        let x = g.placeholder("x", &[batch, in_dim], DType::F32).expect("fresh graph");
        let w = g
            .constant("w", Tensor::from_f32(&[in_dim, out_dim], wv).unwrap())
            .unwrap();
        let b = g.constant("b", Tensor::from_f32(&[out_dim], bv).unwrap()).unwrap();
        let fc = g.add("fc", OpKind::FullyConnected, &[x, w, b]).unwrap();
        g.add("y", OpKind::Relu, &[fc]).unwrap();
        let sig = Signature {
            name: SERVE_SIGNATURE.to_string(),
            inputs: vec![Endpoint::new("x", "x", &[batch, in_dim], DType::F32)],
            outputs: vec![Endpoint::new("y", "y", &[batch, out_dim], DType::F32)],
        };
        ModelBundle::new("tiny_fc", g, vec![sig]).expect("demo bundle is valid")
    }
}

// ---------------------------------------------------------------------------
// Model: the session facade keyed by endpoint names
// ---------------------------------------------------------------------------

/// A loaded model: a [`Session`] plus the bundle's signatures, invoked by
/// endpoint name. One cached execution plan per signature (the plan cache
/// is keyed by the `(feeds, fetches)` shape a signature pins down).
///
/// ```no_run
/// use tf_fpga::tf::model::{Model, ModelBundle};
/// use tf_fpga::tf::{SessionOptions, Tensor, DType};
///
/// let bundle = ModelBundle::tiny_fc_demo(4, 16, 4);
/// let model = Model::from_bundle(bundle, SessionOptions::native_only()).unwrap();
/// let out = model
///     .invoke("serve", &[("x", Tensor::zeros(&[4, 16], DType::F32))])
///     .unwrap();
/// assert_eq!(out[0].shape(), &[4, 4]);
/// model.shutdown();
/// ```
pub struct Model {
    name: String,
    signatures: Vec<Signature>,
    session: Arc<Session>,
}

impl Model {
    /// Load a bundle directory and bring up a session for it.
    pub fn load(dir: impl AsRef<Path>, opts: SessionOptions) -> Result<Model> {
        Model::from_bundle(ModelBundle::load(dir)?, opts)
    }

    pub fn from_bundle(bundle: ModelBundle, opts: SessionOptions) -> Result<Model> {
        let session = Session::new(bundle.graph.clone(), opts)?;
        Ok(Model {
            name: bundle.name,
            signatures: bundle.signatures,
            session: Arc::new(session),
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn signatures(&self) -> &[Signature] {
        &self.signatures
    }

    pub fn signature(&self, name: &str) -> Result<&Signature> {
        find_signature(&self.signatures, &format!("model '{}'", self.name), name)
    }

    /// Resolve endpoint-named feeds into node-named session feeds plus the
    /// signature's fetch list, validating shape/dtype per endpoint so a
    /// bad feed fails here with the endpoint's name and expected meta.
    fn resolve(
        &self,
        signature: &str,
        feeds: &[(&str, Tensor)],
    ) -> Result<(Vec<(String, Tensor)>, Vec<&str>)> {
        let sig = self.signature(signature)?;
        let mut node_feeds = Vec::with_capacity(feeds.len());
        for (name, t) in feeds {
            let ep = sig.input(name).ok_or_else(|| {
                let known: Vec<&str> = sig.inputs.iter().map(|e| e.name.as_str()).collect();
                rt_err(format!(
                    "model '{}' signature '{signature}': no input endpoint '{name}' \
                     (available: {known:?})",
                    self.name
                ))
            })?;
            if t.shape() != ep.shape.as_slice() || t.dtype() != ep.dtype {
                return Err(rt_err(format!(
                    "model '{}' signature '{signature}' input '{name}': expected {:?} {}, \
                     got {:?} {}",
                    self.name,
                    ep.shape,
                    ep.dtype,
                    t.shape(),
                    t.dtype()
                )));
            }
            node_feeds.push((ep.node.clone(), t.clone()));
        }
        for ep in &sig.inputs {
            if !feeds.iter().any(|(n, _)| *n == ep.name) {
                return Err(rt_err(format!(
                    "model '{}' signature '{signature}': input endpoint '{}' not fed \
                     (expected {:?} {})",
                    self.name, ep.name, ep.shape, ep.dtype
                )));
            }
        }
        let fetches: Vec<&str> = sig.outputs.iter().map(|e| e.node.as_str()).collect();
        Ok((node_feeds, fetches))
    }

    /// Run one signature. Outputs come back in the signature's declared
    /// output order. The first call compiles (and caches) the signature's
    /// execution plan; later calls replay it.
    pub fn invoke(&self, signature: &str, feeds: &[(&str, Tensor)]) -> Result<Vec<Tensor>> {
        let (node_feeds, fetches) = self.resolve(signature, feeds)?;
        let feed_refs: Vec<(&str, Tensor)> =
            node_feeds.iter().map(|(n, t)| (n.as_str(), t.clone())).collect();
        self.session.run(&feed_refs, &fetches)
    }

    /// Asynchronous invoke: single-output signatures whose graph qualifies
    /// for the session's tail fast path dispatch without blocking; others
    /// complete synchronously inside the returned [`PendingRun`].
    pub fn invoke_async(
        &self,
        signature: &str,
        feeds: &[(&str, Tensor)],
    ) -> Result<PendingRun> {
        let (node_feeds, fetches) = self.resolve(signature, feeds)?;
        let feed_refs: Vec<(&str, Tensor)> =
            node_feeds.iter().map(|(n, t)| (n.as_str(), t.clone())).collect();
        self.session.run_async(&feed_refs, &fetches)
    }

    /// Precompile the signature's plan (zero-filled feeds); returns the µs
    /// this call spent. Servers call this so the first request replays.
    pub fn warm(&self, signature: &str) -> Result<u64> {
        let sig = self.signature(signature)?;
        let zeros: Vec<(String, Tensor)> = sig
            .inputs
            .iter()
            .map(|e| (e.node.clone(), Tensor::zeros(&e.shape, e.dtype)))
            .collect();
        let feed_refs: Vec<(&str, Tensor)> =
            zeros.iter().map(|(n, t)| (n.as_str(), t.clone())).collect();
        let fetches: Vec<&str> = sig.outputs.iter().map(|e| e.node.as_str()).collect();
        self.session.warm_plan(&feed_refs, &fetches)
    }

    /// The underlying session (plan-cache stats, reconfig stats, ...).
    pub fn session(&self) -> &Arc<Session> {
        &self.session
    }

    pub fn shutdown(&self) {
        self.session.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tf::session::SessionOptions;
    use std::path::PathBuf;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("tf_fpga_model_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// A graph touching every OpKind variant for round-trip coverage.
    fn kitchen_sink_graph() -> Graph {
        let mut g = Graph::new();
        let x = g.placeholder("x", &[2, 64], DType::F32).unwrap();
        let w = g.constant("w", Tensor::from_f32(&[64, 64], vec![0.01; 4096]).unwrap()).unwrap();
        let b = g.constant("b", Tensor::from_f32(&[64], vec![0.5; 64]).unwrap()).unwrap();
        let fc = g.add("fc", OpKind::FullyConnected, &[x, w, b]).unwrap();
        let fcb = g.add("fcb", OpKind::FcBarrier, &[x, w, b]).unwrap();
        let sum = g.add("sum", OpKind::Add, &[fc, fcb]).unwrap();
        let relu = g.add("relu", OpKind::Relu, &[sum]).unwrap();
        g.add("soft", OpKind::Softmax, &[relu]).unwrap();
        let img = g.placeholder("img", &[1, 28, 28], DType::F32).unwrap();
        let q = g.add("q", OpKind::Quantize { frac_bits: 8 }, &[img]).unwrap();
        let c5 = g.add("c5", OpKind::Conv5x5I16, &[q]).unwrap();
        g.add("c3", OpKind::Conv3x3I16, &[q]).unwrap();
        let dq = g.add("dq", OpKind::Dequantize { frac_bits: 8 }, &[c5]).unwrap();
        let mp = g.add("mp", OpKind::MaxPool2, &[dq]).unwrap();
        g.add("rs", OpKind::Reshape { shape: vec![1, 144] }, &[mp]).unwrap();
        let cf = g
            .add(
                "cf",
                OpKind::ConvFixedF32 {
                    weights: "cnn/conv1".into(),
                    filters: 2,
                    cin: 1,
                    kh: 3,
                    kw: 3,
                },
                &[img],
            )
            .unwrap();
        let _ = cf;
        let flat = g.add("flat", OpKind::Reshape { shape: vec![1, 784] }, &[img]).unwrap();
        let short = g.add("short", OpKind::Reshape { shape: vec![1, 64] }, &[b]).unwrap();
        g.add(
            "ff",
            OpKind::FcFixed {
                weights_w: "cnn/fc1_w".into(),
                weights_b: "cnn/fc1_b".into(),
                out_width: 32,
            },
            &[short],
        )
        .unwrap();
        let batch = g.placeholder("batch", &[2, 1, 28, 28], DType::F32).unwrap();
        g.add("cnn", OpKind::MnistCnn, &[batch]).unwrap();
        g.add(
            "cust",
            OpKind::Custom {
                kernel: "my_kernel".into(),
                out_shape: vec![1, 2],
                out_dtype: DType::I32,
            },
            &[flat],
        )
        .unwrap();
        g.set_device(fc, DeviceType::Fpga);
        g.set_device(relu, DeviceType::Cpu);
        g
    }

    #[test]
    fn graphdef_round_trips_every_op_kind() {
        let mut g = kitchen_sink_graph();
        g.finalize().unwrap();
        let doc = graph_to_json(&g).to_string();
        let mut g2 = graph_from_json(&Json::parse(&doc).unwrap()).unwrap();
        g2.finalize().unwrap();
        assert_eq!(g.len(), g2.len());
        for (a, b) in g.nodes().iter().zip(g2.nodes()) {
            assert_eq!(a.name, b.name);
            assert_eq!(format!("{:?}", a.op), format!("{:?}", b.op), "op of '{}'", a.name);
            assert_eq!(a.inputs, b.inputs, "inputs of '{}'", a.name);
            assert_eq!(a.device, b.device, "device of '{}'", a.name);
            assert_eq!(a.out_shape, b.out_shape, "shape of '{}'", a.name);
            assert_eq!(a.out_dtype, b.out_dtype, "dtype of '{}'", a.name);
        }
    }

    #[test]
    fn graphdef_embedded_constants_are_bitwise_exact() {
        let vals = vec![0.1f32, -0.0, 1.0 / 3.0, f32::MIN_POSITIVE, -2.5e-7, 16_777_216.0];
        let mut g = Graph::new();
        g.constant("c", Tensor::from_f32(&[6], vals.clone()).unwrap()).unwrap();
        let doc = graph_to_json(&g).to_string();
        let g2 = graph_from_json(&Json::parse(&doc).unwrap()).unwrap();
        let OpKind::Constant(t) = &g2.node(g2.by_name("c").unwrap()).op else {
            panic!("constant op lost");
        };
        for (a, b) in vals.iter().zip(t.as_f32().unwrap()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} != {b}");
        }
    }

    #[test]
    fn graphdef_rejects_bad_documents() {
        assert!(graph_from_json(&Json::parse("{}").unwrap()).is_err());
        let bad_input = r#"{"nodes":[{"name":"y","op":"relu","inputs":["nope"]}]}"#;
        let err = graph_from_json(&Json::parse(bad_input).unwrap()).unwrap_err();
        assert!(err.to_string().contains("nope"), "{err}");
        let bad_op = r#"{"nodes":[{"name":"y","op":"warp_drive"}]}"#;
        let err = graph_from_json(&Json::parse(bad_op).unwrap()).unwrap_err();
        assert!(err.to_string().contains("warp_drive"), "{err}");
    }

    #[test]
    fn bundle_save_load_round_trip() {
        let bundle = ModelBundle::tiny_fc_demo(4, 16, 4);
        let dir = tmpdir("roundtrip");
        bundle.save(&dir).unwrap();
        let loaded = ModelBundle::load(&dir).unwrap();
        assert_eq!(loaded.name, bundle.name);
        assert_eq!(loaded.graph.len(), bundle.graph.len());
        assert_eq!(loaded.signatures, bundle.signatures);
        // Embedded weights identical bit for bit.
        let w = |b: &ModelBundle| match &b.graph.node(b.graph.by_name("w").unwrap()).op {
            OpKind::Constant(t) => t.as_f32().unwrap().to_vec(),
            _ => panic!("w is a constant"),
        };
        assert_eq!(w(&bundle), w(&loaded));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bundle_records_artifact_refs() {
        let refs = ModelBundle::mnist_layers_demo().artifact_refs();
        assert_eq!(
            refs,
            vec!["cnn/conv1", "cnn/conv2", "cnn/fc1_b", "cnn/fc1_w", "cnn/fc2_b", "cnn/fc2_w"]
        );
        assert!(ModelBundle::mnist_demo(4).artifact_refs().is_empty());
    }

    #[test]
    fn bundle_validation_catches_bad_signatures() {
        let mk_graph = || {
            let mut g = Graph::new();
            let x = g.placeholder("x", &[2, 4], DType::F32).unwrap();
            g.add("y", OpKind::Relu, &[x]).unwrap();
            g
        };
        // Endpoint meta mismatch.
        let sig = Signature {
            name: "serve".into(),
            inputs: vec![Endpoint::new("x", "x", &[2, 4], DType::F32)],
            outputs: vec![Endpoint::new("y", "y", &[9, 9], DType::F32)],
        };
        let err = ModelBundle::new("m", mk_graph(), vec![sig]).unwrap_err();
        assert!(err.to_string().contains("[9, 9]"), "{err}");
        // Uncovered placeholder.
        let sig = Signature {
            name: "serve".into(),
            inputs: vec![],
            outputs: vec![Endpoint::new("y", "y", &[2, 4], DType::F32)],
        };
        let err = ModelBundle::new("m", mk_graph(), vec![sig]).unwrap_err();
        assert!(err.to_string().contains("placeholder 'x'"), "{err}");
        // Unknown node.
        let sig = Signature {
            name: "serve".into(),
            inputs: vec![Endpoint::new("x", "x", &[2, 4], DType::F32)],
            outputs: vec![Endpoint::new("z", "zz", &[2, 4], DType::F32)],
        };
        let err = ModelBundle::new("m", mk_graph(), vec![sig]).unwrap_err();
        assert!(err.to_string().contains("zz"), "{err}");
    }

    #[test]
    fn non_finite_embedded_constants_are_rejected_at_construction() {
        let mut g = Graph::new();
        let c = g
            .constant("w", Tensor::from_f32(&[2], vec![1.0, f32::NAN]).unwrap())
            .unwrap();
        g.add("y", OpKind::Relu, &[c]).unwrap();
        let sig = Signature {
            name: "serve".into(),
            inputs: vec![],
            outputs: vec![Endpoint::new("y", "y", &[2], DType::F32)],
        };
        let err = ModelBundle::new("m", g, vec![sig]).unwrap_err();
        assert!(err.to_string().contains("constant 'w'"), "{err}");
        assert!(err.to_string().contains("non-finite"), "{err}");
    }

    #[test]
    fn model_invoke_matches_direct_session_run() {
        let bundle = ModelBundle::tiny_fc_demo(2, 8, 3);
        let sess = Session::new(bundle.graph.clone(), SessionOptions::native_only()).unwrap();
        let model = Model::from_bundle(bundle, SessionOptions::native_only()).unwrap();
        let x = Tensor::from_f32(&[2, 8], (0..16).map(|i| i as f32 * 0.1 - 0.8).collect())
            .unwrap();
        let got = model.invoke(SERVE_SIGNATURE, &[("x", x.clone())]).unwrap();
        let want = sess.run(&[("x", x)], &["y"]).unwrap();
        assert_eq!(got[0], want[0]);
        assert_eq!(model.session().plan_cache_stats().compiles, 1);
        model.shutdown();
        sess.shutdown();
    }

    #[test]
    fn named_feed_errors_carry_endpoint_and_meta() {
        let model = Model::from_bundle(
            ModelBundle::tiny_fc_demo(2, 8, 3),
            SessionOptions::native_only(),
        )
        .unwrap();
        // Wrong shape: error names the endpoint, expected and got metas.
        let err = model
            .invoke("serve", &[("x", Tensor::zeros(&[3, 8], DType::F32))])
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("input 'x'"), "{msg}");
        assert!(msg.contains("[2, 8]") && msg.contains("[3, 8]"), "{msg}");
        // Wrong dtype.
        let err = model
            .invoke("serve", &[("x", Tensor::zeros(&[2, 8], DType::I16))])
            .unwrap_err();
        assert!(err.to_string().contains("i16"), "{err}");
        // Unknown endpoint name lists the valid ones.
        let err = model
            .invoke("serve", &[("nope", Tensor::zeros(&[2, 8], DType::F32))])
            .unwrap_err();
        assert!(err.to_string().contains("\"x\""), "{err}");
        // Missing feed names the endpoint it wants.
        let err = model.invoke("serve", &[]).unwrap_err();
        assert!(err.to_string().contains("'x' not fed"), "{err}");
        // Unknown signature lists the available ones.
        let err = model.invoke("wat", &[]).unwrap_err();
        assert!(err.to_string().contains("serve"), "{err}");
        model.shutdown();
    }

    #[test]
    fn model_invoke_async_matches_sync() {
        let model = Model::from_bundle(
            ModelBundle::tiny_fc_demo(2, 8, 3),
            SessionOptions::native_only(),
        )
        .unwrap();
        let x = Tensor::from_f32(&[2, 8], vec![0.25; 16]).unwrap();
        let pending = model.invoke_async("serve", &[("x", x.clone())]).unwrap();
        let async_out = pending.wait(Some(std::time::Duration::from_secs(30))).unwrap();
        let sync_out = model.invoke("serve", &[("x", x)]).unwrap();
        assert_eq!(async_out[0], sync_out[0]);
        model.shutdown();
    }

    #[test]
    fn warm_caches_the_signature_plan() {
        let model = Model::from_bundle(
            ModelBundle::tiny_fc_demo(2, 8, 3),
            SessionOptions::native_only(),
        )
        .unwrap();
        let us = model.warm("serve").unwrap();
        assert!(us >= 1);
        assert_eq!(model.session().plan_cache_stats().compiles, 1);
        model
            .invoke("serve", &[("x", Tensor::zeros(&[2, 8], DType::F32))])
            .unwrap();
        let s = model.session().plan_cache_stats();
        assert_eq!((s.compiles, s.hits), (1, 1), "invoke replays the warmed plan");
        model.shutdown();
    }

    #[test]
    fn mnist_demo_serves_through_model_facade() {
        let model = Model::from_bundle(
            ModelBundle::mnist_demo(2),
            SessionOptions::native_only(),
        )
        .unwrap();
        let out = model
            .invoke("serve", &[("x", Tensor::zeros(&[2, 1, 28, 28], DType::F32))])
            .unwrap();
        assert_eq!(out[0].shape(), &[2, 10]);
        model.shutdown();
    }

    #[test]
    fn mnist_layers_demo_resolves_weight_refs() {
        let model = Model::from_bundle(
            ModelBundle::mnist_layers_demo(),
            SessionOptions::native_only(),
        )
        .unwrap();
        let out = model
            .invoke("serve", &[("x", Tensor::zeros(&[1, 28, 28], DType::F32))])
            .unwrap();
        assert_eq!(out[0].shape(), &[1, 10]);
        model.shutdown();
    }
}
