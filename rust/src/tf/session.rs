//! The session: TF's user-facing entry point, owning the whole backend
//! stack — HSA runtime, CPU + FPGA agents, queues, kernel registry, PJRT
//! service, artifact store — exactly the "device/kernel setup" cost that
//! Table II's first row measures.
//!
//! Three execution paths:
//!
//! * [`Session::run`] — compiles the `(feeds, fetches)` shape once into an
//!   [`ExecutionPlan`] (pruning, constant folding, op fusion, slot-based
//!   buffer arena), caches it, and *replays* it — no graph walking, no
//!   per-run name/registry lookups; independent steps dispatch
//!   concurrently across device queues.
//! * [`Session::run_async`] — pipelined: for graphs whose fetch is one
//!   device-placed op fed only by structural ops (the serving shape),
//!   enqueue the AQL packet and return a [`PendingRun`] immediately; the
//!   caller overlaps further submissions with the in-flight kernel and
//!   harvests the result off the completion signal. Other graph shapes
//!   transparently fall back to a (plan-replayed) synchronous run.
//! * [`Session::run_interpreted`] — the legacy topological walk (one
//!   blocking HSA dispatch per placed node), kept as the reference the
//!   plan path is property-tested against and as the benchmark baseline.

use crate::cpu::a53::CpuKernelClass;
use crate::cpu::device::{CpuAgent, CpuKernel};
use crate::fpga::datapath::RoleOp;
use crate::fpga::device::{ComputeBinding, FpgaAgent, FpgaConfig};
use crate::fpga::roles;
use crate::hsa::agent::DeviceType;
use crate::hsa::error::{HsaError, Result};
use crate::hsa::packet::KernelArgs;
use crate::hsa::queue::Queue;
use crate::hsa::runtime::HsaRuntime;
use crate::hsa::signal::Signal;
use crate::reconfig::manager::ReconfigStats;
use crate::reconfig::policy::PolicyKind;
use crate::reconfig::scheduler::{PrefetchPolicy, PrefetchScheduler};
use crate::runtime::artifact::ArtifactStore;
use crate::runtime::pjrt::PjrtService;
use crate::sharding::{FpgaPool, RouteGuard, Router, ShardAgentReport, ShardStrategy};
use crate::tf::dtype::DType;
use crate::tf::executor::{self, ExecEnv, RunStats};
use crate::tf::graph::{Graph, NodeId, OpKind};
use crate::tf::kernel::{fused_relu_name, KernelRegistry};
use crate::tf::placer::{place, Placement, PlacementMap, PlacerOptions};
use crate::tf::plan::{ExecutionPlan, PlanOptions};
use crate::tf::tensor::Tensor;
use crate::util::prng::Rng;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Session configuration.
pub struct SessionOptions {
    /// Artifact directory (None = `$TF_FPGA_ARTIFACTS` or `./artifacts`).
    pub artifacts_dir: Option<std::path::PathBuf>,
    /// Bring up PJRT and bind FPGA roles to their AOT modules. When false
    /// (or artifacts are missing) roles use native datapath numerics.
    pub use_pjrt: bool,
    pub num_regions: usize,
    pub policy: PolicyKind,
    pub prefer_fpga: bool,
    pub allow_soft_placement: bool,
    /// Sleep modeled device durations (reconfig/exec) for realistic
    /// wall-clock behaviour; off for benches that read virtual time.
    pub realtime: bool,
    /// Optional event trace fed by the FPGA agent (Chrome-trace export).
    pub trace: Option<crate::trace::recorder::TraceRecorder>,
    /// Packet processors per device queue. 1 (the default) preserves
    /// strict in-order kernel execution; >1 lets independent dispatches on
    /// one device run concurrently (the FPGA executes one kernel per PR
    /// region), which the async serving pipeline relies on. See
    /// `HsaRuntime::create_queue_with_processors` for ordering caveats.
    pub dispatch_workers: usize,
    /// Plan-compiler pass toggles (fusion, constant folding). Both on by
    /// default; `run` always goes through cached plans either way.
    pub plan: PlanOptions,
    /// Number of independent FPGA agents (each with its own PR regions,
    /// ICAP and eviction policy). 1 — the paper's single device — by
    /// default; >1 shards FPGA dispatches across the pool via
    /// `shard_strategy` (see [`crate::sharding`]).
    pub fpga_pool: usize,
    /// How the pool router assigns dispatches to agents. Irrelevant at
    /// `fpga_pool == 1`.
    pub shard_strategy: ShardStrategy,
    /// Seed for stochastic components (today: the `random` eviction
    /// policy; agent `i` of a pool derives `seed + i`), so multi-agent
    /// runs are reproducible end to end.
    pub seed: u64,
    /// Pool health policy: stall detection threshold, completion-probe
    /// interval and retry budget for dispatches caught on a dying agent.
    /// Irrelevant at `fpga_pool == 1` (nowhere else to retry).
    pub health: crate::sharding::HealthPolicy,
    /// Predictive reconfiguration: prefetch upcoming roles onto idle PR
    /// regions during replay (plan horizon) and between batches (queued
    /// demand). Disabled by default — prefetch never changes outputs, but
    /// it does change reconfiguration accounting, so opting in is
    /// explicit (`tf-fpga serve --prefetch-depth N`).
    pub prefetch: PrefetchPolicy,
}

impl Default for SessionOptions {
    fn default() -> Self {
        SessionOptions {
            artifacts_dir: None,
            use_pjrt: true,
            num_regions: 2,
            policy: PolicyKind::Lru,
            prefer_fpga: true,
            allow_soft_placement: true,
            realtime: false,
            trace: None,
            dispatch_workers: 1,
            plan: PlanOptions::default(),
            fpga_pool: 1,
            shard_strategy: ShardStrategy::KernelAffinity,
            seed: 0xF06A,
            health: crate::sharding::HealthPolicy::default(),
            prefetch: PrefetchPolicy::default(),
        }
    }
}

impl SessionOptions {
    /// CPU-only baseline (Table III denominator runs).
    pub fn cpu_baseline() -> SessionOptions {
        SessionOptions { prefer_fpga: false, use_pjrt: false, ..Default::default() }
    }

    /// No-PJRT lightweight options (unit tests / property tests).
    pub fn native_only() -> SessionOptions {
        SessionOptions { use_pjrt: false, ..Default::default() }
    }
}

/// Fixed weights shared by every backend implementation of the built-in
/// kernels (loaded from artifacts when present so PJRT modules agree, else
/// synthesized deterministically).
pub struct WeightBank {
    pub conv5_w: Vec<i16>, // (1,1,5,5)
    pub conv3_w: Vec<i16>, // (2,1,3,3)
    pub cnn_conv1: Vec<f32>, // (2,1,3,3)
    pub cnn_conv2: Vec<f32>, // (4,2,5,5)
    pub cnn_fc1_w: Vec<f32>, // (64,32)
    pub cnn_fc1_b: Vec<f32>, // (32,)
    pub cnn_fc2_w: Vec<f32>, // (32,10)
    pub cnn_fc2_b: Vec<f32>, // (10,)
    pub role1_w: Vec<f32>, // (64,64)
    pub role1_b: Vec<f32>, // (64,)
    pub conv_shift: u32,
    pub from_artifacts: bool,
}

impl WeightBank {
    pub fn load(store: Option<&ArtifactStore>) -> Result<WeightBank> {
        if let Some(s) = store {
            let g = |n: &str| s.load_weight_f32(n).map(|(_, v)| v);
            let gi = |n: &str| s.load_weight_i16(n).map(|(_, v)| v);
            return Ok(WeightBank {
                conv5_w: gi("role3/w")?,
                conv3_w: gi("role4/w")?,
                cnn_conv1: g("cnn/conv1")?,
                cnn_conv2: g("cnn/conv2")?,
                cnn_fc1_w: g("cnn/fc1_w")?,
                cnn_fc1_b: g("cnn/fc1_b")?,
                cnn_fc2_w: g("cnn/fc2_w")?,
                cnn_fc2_b: g("cnn/fc2_b")?,
                role1_w: g("role1/w")?,
                role1_b: g("role1/b")?,
                conv_shift: s.conv_shift,
                from_artifacts: true,
            });
        }
        // Deterministic synthetic weights (PJRT-free mode).
        let mut rng = Rng::new(0x5EED_1027);
        let mut f32s = |n: usize, std: f32| {
            let mut v = vec![0f32; n];
            rng.fill_f32_normal(&mut v, 0.0, std);
            v
        };
        let cnn_conv1 = f32s(2 * 1 * 3 * 3, 0.2);
        let cnn_conv2 = f32s(4 * 2 * 5 * 5, 0.15);
        let cnn_fc1_w = f32s(64 * 32, 0.1);
        let cnn_fc2_w = f32s(32 * 10, 0.1);
        let role1_w = f32s(64 * 64, 0.1);
        let role1_b = f32s(64, 0.1);
        let mut i16s = |n: usize| {
            let mut v = vec![0i16; n];
            rng.fill_i16(&mut v, -128, 127);
            v
        };
        Ok(WeightBank {
            conv5_w: i16s(25),
            conv3_w: i16s(18),
            cnn_conv1,
            cnn_conv2,
            cnn_fc1_w,
            cnn_fc1_b: vec![0.0; 32],
            cnn_fc2_w,
            cnn_fc2_b: vec![0.0; 10],
            role1_w,
            role1_b,
            conv_shift: 8,
            from_artifacts: false,
        })
    }
}

/// Timing breakdown of session construction (Table II row 1).
#[derive(Debug, Clone, Copy, Default)]
pub struct SetupTiming {
    pub total_us: u128,
    pub pjrt_client_us: u128,
    pub pjrt_compile_us: u128,
    pub hsa_bringup_us: u128,
}

/// A dispatched-but-not-yet-retired graph run (see [`Session::run_async`]).
///
/// Holds the AQL completion signal and the kernarg output slot of the
/// in-flight kernel. Dropping a `PendingRun` without waiting is safe — the
/// kernel still retires; its outputs are discarded.
pub struct PendingRun {
    state: PendingState,
}

enum PendingState {
    /// Fallback path: the run already completed synchronously.
    Ready(Vec<Tensor>),
    /// Fast path: one device kernel is in flight.
    InFlight {
        completion: Signal,
        args: KernelArgs,
        node_name: String,
        expected_shape: Vec<usize>,
        /// Keeps the routed agent's in-flight gauge truthful until the
        /// result is harvested (or the run is dropped unharvested).
        _route: Option<RouteGuard>,
        /// Router slot index the dispatch landed on (None when not
        /// shard-routed) — lets harvesters attribute a wedged dispatch to
        /// its agent and retry elsewhere.
        route_slot: Option<usize>,
    },
}

impl PendingRun {
    fn ready(outputs: Vec<Tensor>) -> PendingRun {
        PendingRun { state: PendingState::Ready(outputs) }
    }

    /// Whether the result can be harvested without blocking.
    pub fn is_done(&self) -> bool {
        match &self.state {
            PendingState::Ready(_) => true,
            PendingState::InFlight { completion, .. } => completion.is_zero(),
        }
    }

    /// The completion signal of the in-flight dispatch (None when the run
    /// was satisfied synchronously). Callers can park on it directly.
    pub fn signal(&self) -> Option<&Signal> {
        match &self.state {
            PendingState::Ready(_) => None,
            PendingState::InFlight { completion, .. } => Some(completion),
        }
    }

    /// Router slot index of the in-flight dispatch (None when the run was
    /// satisfied synchronously or was not shard-routed).
    pub fn route_slot(&self) -> Option<usize> {
        match &self.state {
            PendingState::Ready(_) => None,
            PendingState::InFlight { route_slot, .. } => *route_slot,
        }
    }

    /// Abandon the run for a retry elsewhere, yielding its completion
    /// signal and route guard so the caller can park them as a zombie on
    /// the router (keeping the dying agent's load gauge truthful until the
    /// wedged execution actually finishes). None for synchronous runs —
    /// nothing is in flight.
    pub fn abandon_for_retry(self) -> Option<(Signal, Option<RouteGuard>)> {
        match self.state {
            PendingState::Ready(_) => None,
            PendingState::InFlight { completion, _route, .. } => {
                Some((completion, _route))
            }
        }
    }

    /// Block until the kernel retires and return the fetched tensors.
    pub fn wait(self, timeout: Option<Duration>) -> Result<Vec<Tensor>> {
        match self.state {
            PendingState::Ready(outputs) => Ok(outputs),
            PendingState::InFlight {
                completion, args, node_name, expected_shape, _route, ..
            } => {
                completion.wait_eq(0, timeout)?;
                let mut outs = match args.take_output() {
                    Some(Ok(outs)) => outs,
                    Some(Err(msg)) => return Err(HsaError::KernelFailed(msg)),
                    None => {
                        return Err(HsaError::KernelFailed(
                            "kernel retired without writing outputs".into(),
                        ))
                    }
                };
                if outs.len() != 1 {
                    return Err(HsaError::Runtime(format!(
                        "kernel for '{node_name}' returned {} outputs",
                        outs.len()
                    )));
                }
                let out = outs.pop().unwrap();
                if !expected_shape.is_empty() && out.shape() != expected_shape.as_slice() {
                    return Err(HsaError::Runtime(format!(
                        "node '{node_name}': kernel produced {:?}, inference said {:?}",
                        out.shape(),
                        expected_shape
                    )));
                }
                Ok(vec![out])
            }
        }
    }
}

/// Cache key of a compiled plan: the fetch list (order-sensitive — it is
/// the output order) plus the name-sorted feed signature (name, shape,
/// dtype). A feed whose shape changes therefore misses the cache instead
/// of replaying a stale plan. Only feeds naming a graph placeholder enter
/// the key — extraneous feeds cannot affect the plan, and keying on them
/// would let a caller with a varying junk feed grow the cache per call.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct PlanKey {
    fetches: Vec<String>,
    feeds: Vec<(String, Vec<usize>, DType)>,
}

impl PlanKey {
    fn new(graph: &Graph, feeds: &HashMap<String, Tensor>, fetches: &[&str]) -> PlanKey {
        let mut feed_sig: Vec<(String, Vec<usize>, DType)> = feeds
            .iter()
            .filter(|(n, _)| {
                graph
                    .by_name(n)
                    .is_some_and(|id| matches!(graph.node(id).op, OpKind::Placeholder { .. }))
            })
            .map(|(n, t)| (n.clone(), t.shape().to_vec(), t.dtype()))
            .collect();
        feed_sig.sort();
        PlanKey {
            fetches: fetches.iter().map(|s| s.to_string()).collect(),
            feeds: feed_sig,
        }
    }
}

/// Plan-cache accounting (see [`Session::plan_cache_stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlanCacheStats {
    /// Plans currently cached.
    pub entries: usize,
    /// Compilations performed (cache misses).
    pub compiles: u64,
    /// Replays served from the cache.
    pub hits: u64,
    /// Total time spent compiling plans, in µs.
    pub compile_us_total: u64,
}

/// The session.
pub struct Session {
    graph: Graph,
    placement: PlacementMap,
    runtime: HsaRuntime,
    queues: HashMap<DeviceType, Queue>,
    registry: KernelRegistry,
    cpu: Arc<CpuAgent>,
    /// FPGA dispatch router over the agent pool (a pool of one for the
    /// default single-device configuration).
    router: Router,
    weights: Arc<WeightBank>,
    _pjrt: Option<PjrtService>,
    setup: SetupTiming,
    plan_opts: PlanOptions,
    plans: RwLock<HashMap<PlanKey, Arc<ExecutionPlan>>>,
    /// Serializes compilations (double-checked against `plans`), so two
    /// threads missing on the same key never both run the compile — which
    /// matters because constant folding issues real dispatches.
    plan_compile_lock: Mutex<()>,
    plan_compiles: AtomicU64,
    plan_hits: AtomicU64,
    plan_compile_us: AtomicU64,
    /// Predictive-reconfiguration policy applied to every plan replay and
    /// to the demand-driven warm paths (see [`Session::prefetch_hot`]).
    prefetch: PrefetchPolicy,
    /// The recorder the session (and the FPGA agents, via `FpgaConfig`)
    /// emits onto — request spans, plan dispatches and device events
    /// share this one timeline.
    trace: Option<crate::trace::TraceRecorder>,
}

impl Session {
    /// Build the full backend and place `graph` onto it.
    pub fn new(mut graph: Graph, opts: SessionOptions) -> Result<Session> {
        let t_total = Instant::now();
        if !graph.is_finalized() {
            graph.finalize()?;
        }

        // Artifacts (weights always come from here when available, so all
        // session configurations — FPGA-placed, CPU baseline, PJRT-free —
        // compute with identical fixed weights).
        let dir = opts.artifacts_dir.clone().unwrap_or_else(|| {
            std::env::var("TF_FPGA_ARTIFACTS")
                .unwrap_or_else(|_| "artifacts".into())
                .into()
        });
        let store = ArtifactStore::open(dir).ok();
        let weights = Arc::new(WeightBank::load(store.as_ref())?);

        let mut setup = SetupTiming::default();
        let mut pjrt = None;
        if let (true, Some(store)) = (opts.use_pjrt, &store) {
            let t = Instant::now();
            // PJRT is an acceleration of the artifact path, not a
            // correctness dependency: if the backend is unavailable (built
            // without the `pjrt` feature, or the XLA client fails) degrade
            // to native-kernel numerics instead of failing the session.
            match PjrtService::start() {
                Ok(svc) => {
                    setup.pjrt_client_us = t.elapsed().as_micros();
                    let t = Instant::now();
                    for name in [
                        "role1_fc",
                        "role2_fc_barrier",
                        "role3_conv5x5",
                        "role4_conv3x3",
                        "mnist_cnn",
                    ] {
                        if let Ok(meta) = store.module(name) {
                            // A module that fails to compile just stays on
                            // native numerics (same degrade rule as above);
                            // the other modules still get PJRT.
                            if let Err(e) = svc.handle().load_module(meta) {
                                eprintln!(
                                    "session: PJRT module '{name}' unavailable, \
                                     using native kernel: {e}"
                                );
                            }
                        }
                    }
                    setup.pjrt_compile_us = t.elapsed().as_micros();
                    pjrt = Some(svc);
                }
                Err(e) => {
                    eprintln!("session: PJRT unavailable, using native kernels: {e}");
                }
            }
        }

        // HSA bring-up: agents (CPU + the FPGA pool), kernels, queues,
        // registry. Every pool member gets its own PR regions, ICAP and
        // eviction-policy instance (seeded per agent for reproducibility);
        // roles register on all members under one shared kernel-object id
        // so placement and compiled plans stay pool-agnostic.
        let t_hsa = Instant::now();
        let cpu = CpuAgent::with_defaults();
        let pool = FpgaPool::new(opts.fpga_pool, |i| FpgaConfig {
            num_regions: opts.num_regions,
            policy: opts.policy.build(opts.seed.wrapping_add(i as u64)),
            realtime: opts.realtime,
            realtime_scale: 1.0,
            trace: opts.trace.clone(),
        });
        let mut registry = KernelRegistry::new();
        register_cpu_kernels(&cpu, &weights, &mut registry);
        register_fpga_roles(
            &pool,
            &weights,
            pjrt.as_ref().map(|p| p.handle()),
            store.as_ref(),
            &mut registry,
        );
        register_graph_kernels(&graph, &cpu, &pool, &mut registry);

        let runtime = HsaRuntime::builder()
            .with_agent(cpu.clone())
            .with_fpga_pool(&pool)
            .build();
        let workers = opts.dispatch_workers.max(1);
        let mut queues = HashMap::new();
        queues.insert(
            DeviceType::Cpu,
            runtime.create_queue_with_processors(
                runtime.agent_by_type(DeviceType::Cpu)?,
                256,
                workers,
            ),
        );
        // One AQL queue (with its own processor pool) per FPGA agent; the
        // router owns the full set. The per-device map keeps agent 0's
        // queue so router-less paths (`Session::queue`, bare ExecEnvs)
        // stay valid.
        let fpga_slots: Vec<(Arc<FpgaAgent>, Queue)> = pool
            .agents()
            .iter()
            .map(|agent| {
                let q = runtime.create_queue_with_processors(
                    Arc::clone(agent) as Arc<dyn crate::hsa::agent::Agent>,
                    256,
                    workers,
                );
                (Arc::clone(agent), q)
            })
            .collect();
        queues.insert(DeviceType::Fpga, fpga_slots[0].1.clone());
        let mut router = Router::with_health_policy(
            fpga_slots,
            opts.shard_strategy,
            opts.health.clone(),
        );
        if let Some(tr) = &opts.trace {
            router.set_trace(tr.clone());
        }
        setup.hsa_bringup_us = t_hsa.elapsed().as_micros();

        let placement = place(
            &graph,
            &registry,
            PlacerOptions {
                allow_soft_placement: opts.allow_soft_placement,
                prefer_fpga: opts.prefer_fpga,
            },
        )?;
        setup.total_us = t_total.elapsed().as_micros();

        Ok(Session {
            graph,
            placement,
            runtime,
            queues,
            registry,
            cpu,
            router,
            weights,
            _pjrt: pjrt,
            setup,
            plan_opts: opts.plan,
            plans: RwLock::new(HashMap::new()),
            plan_compile_lock: Mutex::new(()),
            plan_compiles: AtomicU64::new(0),
            plan_hits: AtomicU64::new(0),
            plan_compile_us: AtomicU64::new(0),
            prefetch: opts.prefetch,
            trace: opts.trace.clone(),
        })
    }

    /// Run the graph: feed placeholders, fetch outputs by node name.
    ///
    /// The first call for a given `(feeds, fetches)` shape compiles an
    /// [`ExecutionPlan`] (prune → fold constants → fuse ops → allocate
    /// buffer slots) and caches it; every later call replays the plan —
    /// the serving hot path never walks the graph again.
    ///
    /// # Example
    ///
    /// ```no_run
    /// use tf_fpga::tf::{DType, Graph, OpKind, Session, SessionOptions, Tensor};
    ///
    /// let mut g = Graph::new();
    /// let x = g.placeholder("x", &[1, 4], DType::F32).unwrap();
    /// let w = g.constant("w", Tensor::zeros(&[4, 2], DType::F32)).unwrap();
    /// let b = g.constant("b", Tensor::zeros(&[2], DType::F32)).unwrap();
    /// g.add("y", OpKind::FullyConnected, &[x, w, b]).unwrap();
    ///
    /// let sess = Session::new(g, SessionOptions::native_only()).unwrap();
    /// let out = sess
    ///     .run(&[("x", Tensor::zeros(&[1, 4], DType::F32))], &["y"])
    ///     .unwrap();
    /// assert_eq!(out[0].shape(), &[1, 2]);
    /// assert_eq!(sess.plan_cache_stats().compiles, 1); // cached for replay
    /// sess.shutdown();
    /// ```
    pub fn run(
        &self,
        feeds: &[(&str, Tensor)],
        fetches: &[&str],
    ) -> Result<Vec<Tensor>> {
        self.run_with_stats(feeds, fetches).map(|(t, _)| t)
    }

    pub fn run_with_stats(
        &self,
        feeds: &[(&str, Tensor)],
        fetches: &[&str],
    ) -> Result<(Vec<Tensor>, RunStats)> {
        let feeds: HashMap<String, Tensor> =
            feeds.iter().map(|(k, v)| (k.to_string(), v.clone())).collect();
        let plan = self.cached_plan(&feeds, fetches)?;
        let env = ExecEnv { runtime: &self.runtime, queues: &self.queues, router: Some(&self.router) };
        plan.replay_traced(&env, &feeds, self.prefetch, self.trace.as_ref().map(|t| (t, "plan")))
    }

    /// The legacy interpreted path: topological walk, one blocking dispatch
    /// per placed node, no pruning/folding/fusion. Kept as the reference
    /// the plan replayer is property-tested against and as the baseline in
    /// `benches/dispatch_hotpath.rs`.
    pub fn run_interpreted(
        &self,
        feeds: &[(&str, Tensor)],
        fetches: &[&str],
    ) -> Result<(Vec<Tensor>, RunStats)> {
        let feeds: HashMap<String, Tensor> =
            feeds.iter().map(|(k, v)| (k.to_string(), v.clone())).collect();
        let env = ExecEnv { runtime: &self.runtime, queues: &self.queues, router: Some(&self.router) };
        executor::run(&self.graph, &self.placement, &env, &feeds, fetches)
    }

    /// Get-or-compile the plan for this `(feeds, fetches)` shape.
    fn cached_plan(
        &self,
        feeds: &HashMap<String, Tensor>,
        fetches: &[&str],
    ) -> Result<Arc<ExecutionPlan>> {
        // Reject mis-shaped feeds before touching the cache: a plan whose
        // Feed step can never succeed must not become a permanent entry.
        // Note this validates every fed placeholder — including ones the
        // fetch cone would prune — so the plan path is deliberately
        // stricter than `run_interpreted` (which skips dead placeholders).
        for (name, t) in feeds {
            let Some(id) = self.graph.by_name(name) else { continue };
            if let OpKind::Placeholder { shape, dtype } = &self.graph.node(id).op {
                executor::check_feed(name, shape, *dtype, t)?;
            }
        }
        let key = PlanKey::new(&self.graph, feeds, fetches);
        if let Some(plan) = self.plans.read().unwrap().get(&key) {
            self.plan_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(plan));
        }
        // Serialize compiles, then re-check: a racing thread may have
        // compiled this key while we waited. The `plans` lock itself stays
        // free during compilation (folding may dispatch kernels), so
        // cache *hits* on other keys never block behind a compile.
        let _compiling = self.plan_compile_lock.lock().unwrap();
        if let Some(plan) = self.plans.read().unwrap().get(&key) {
            self.plan_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(plan));
        }
        let t0 = Instant::now();
        let env = ExecEnv { runtime: &self.runtime, queues: &self.queues, router: Some(&self.router) };
        let plan = Arc::new(ExecutionPlan::compile(
            &self.graph,
            &self.placement,
            &self.registry,
            &env,
            fetches,
            self.plan_opts,
        )?);
        self.plan_compiles.fetch_add(1, Ordering::Relaxed);
        // 1 µs floor: a compile always registers in the accounting, even
        // for graphs small enough to compile sub-microsecond.
        self.plan_compile_us
            .fetch_add((t0.elapsed().as_micros() as u64).max(1), Ordering::Relaxed);
        self.plans.write().unwrap().insert(key, Arc::clone(&plan));
        Ok(plan)
    }

    /// Precompile and cache the plan for a `(feeds, fetches)` shape without
    /// running it (servers call this at startup so the first request does
    /// not pay compile latency). Returns the time *this call* spent, in µs
    /// (floored at 1) — timed locally, so concurrent compiles on other
    /// threads are never attributed to this caller.
    pub fn warm_plan(
        &self,
        feeds: &[(&str, Tensor)],
        fetches: &[&str],
    ) -> Result<u64> {
        let feeds: HashMap<String, Tensor> =
            feeds.iter().map(|(k, v)| (k.to_string(), v.clone())).collect();
        let t0 = Instant::now();
        let plan = self.cached_plan(&feeds, fetches)?;
        // Prewarm routes through the scheduler: with prefetch enabled, the
        // plan's first roles start loading now, so the first real request
        // finds them resident (or mid-transfer) instead of cold.
        if self.prefetch.enabled {
            let mut scheduler = PrefetchScheduler::new(self.prefetch);
            scheduler.pump(&self.router, plan.horizon(), 0);
        }
        Ok((t0.elapsed().as_micros() as u64).max(1))
    }

    /// Demand-driven prefetch: walk the router's queued-demand hints
    /// (hottest kernel first) and start background loads for the hot roles
    /// that are not resident anywhere. The serving frontend calls this
    /// after publishing batch-queue depths (`hint_demand`), turning the
    /// admission queue into a prefetch signal. No-op when prefetch is
    /// disabled.
    pub fn prefetch_hot(&self) {
        if self.prefetch.enabled {
            let mut scheduler = PrefetchScheduler::new(self.prefetch);
            scheduler.pump_demand(&self.router);
        }
    }

    /// Tell the eviction policies a batch round completed: queued-demand
    /// hints decay (instead of pinning stale-hot roles forever — see
    /// `QueueAwareLru::decay_demand`). The async server calls this as its
    /// completer retires batches.
    pub fn note_batch_retired(&self) {
        self.router.decay_demand();
    }

    /// Plan-cache accounting: entries, compiles (misses), replay hits and
    /// cumulative compile time.
    pub fn plan_cache_stats(&self) -> PlanCacheStats {
        PlanCacheStats {
            entries: self.plans.read().unwrap().len(),
            compiles: self.plan_compiles.load(Ordering::Relaxed),
            hits: self.plan_hits.load(Ordering::Relaxed),
            compile_us_total: self.plan_compile_us.load(Ordering::Relaxed),
        }
    }

    /// Asynchronous run: dispatch without waiting for retirement.
    ///
    /// Fast path — a single fetch whose node is device-placed and fed only
    /// by structural ops (placeholders / constants / reshapes): the kernel
    /// packet is enqueued on the device's AQL queue and a [`PendingRun`]
    /// is returned immediately, before the kernel executes. Combined with
    /// a multi-processor queue (`SessionOptions::dispatch_workers` > 1),
    /// callers can keep several runs in flight across PR regions and
    /// harvest them in completion order — the backbone of the async
    /// serving pipeline in [`crate::serve`].
    ///
    /// Any other graph shape (multiple fetches, chained device ops) is
    /// executed synchronously and returned as an already-completed
    /// `PendingRun`, so the call is total over all graphs.
    pub fn run_async(
        &self,
        feeds: &[(&str, Tensor)],
        fetches: &[&str],
    ) -> Result<PendingRun> {
        if fetches.len() == 1 {
            if let Some(pending) = self.try_dispatch_tail(feeds, fetches[0])? {
                return Ok(pending);
            }
        }
        self.run(feeds, fetches).map(PendingRun::ready)
    }

    /// Attempt the single-device-tail fast path; `Ok(None)` means the
    /// graph shape needs the full executor.
    fn try_dispatch_tail(
        &self,
        feeds: &[(&str, Tensor)],
        fetch: &str,
    ) -> Result<Option<PendingRun>> {
        let id = self
            .graph
            .by_name(fetch)
            .ok_or_else(|| HsaError::Runtime(format!("fetch '{fetch}' not in graph")))?;
        let (device, kernel_object) = match self.placement.by_node.get(&id) {
            Some(Placement::Device { device, kernel_object }) => (*device, *kernel_object),
            _ => return Ok(None),
        };
        let node = self.graph.node(id);
        let mut inputs = Vec::with_capacity(node.inputs.len());
        for &input in &node.inputs {
            match self.eval_structural(input, feeds)? {
                Some(t) => inputs.push(t),
                None => return Ok(None),
            }
        }
        // FPGA dispatches shard across the pool: each in-flight serving
        // batch can land on a different agent, which is what lets separate
        // micro-batch lanes execute truly in parallel at pool > 1.
        let env = ExecEnv {
            runtime: &self.runtime,
            queues: &self.queues,
            router: Some(&self.router),
        };
        let (route_slot, queue, route) = env.route_indexed(device, kernel_object)?;
        let (completion, args) = self.runtime.dispatch_async(&queue, kernel_object, inputs)?;
        Ok(Some(PendingRun {
            state: PendingState::InFlight {
                completion,
                args,
                node_name: node.name.clone(),
                expected_shape: node.out_shape.clone(),
                _route: route,
                route_slot,
            },
        }))
    }

    /// Evaluate a structural (inline-placed) node without the executor.
    /// `Ok(None)` when the node (or anything upstream) needs a device
    /// dispatch of its own.
    fn eval_structural(
        &self,
        id: NodeId,
        feeds: &[(&str, Tensor)],
    ) -> Result<Option<Tensor>> {
        let node = self.graph.node(id);
        match &node.op {
            OpKind::Placeholder { shape, dtype } => {
                let t = feeds
                    .iter()
                    .find(|(n, _)| *n == node.name)
                    .map(|(_, t)| t)
                    .ok_or_else(|| {
                        HsaError::Runtime(format!("placeholder '{}' not fed", node.name))
                    })?;
                executor::check_feed(&node.name, shape, *dtype, t)?;
                Ok(Some(t.clone()))
            }
            OpKind::Constant(t) => Ok(Some(t.clone())),
            OpKind::Reshape { shape } => match self.eval_structural(node.inputs[0], feeds)? {
                Some(t) => Ok(Some(t.reshape(shape)?)),
                None => Ok(None),
            },
            _ => Ok(None),
        }
    }

    /// Queued-demand hint for the FPGA eviction policies: `queued`
    /// requests are waiting on `kernel` (0 clears the hint). The hint
    /// reaches *every* pool agent's policy and the router's replication
    /// heuristic (`KernelAffinity` spills hot kernels onto idle agents).
    /// No-op when the kernel has no FPGA implementation; demand-blind
    /// policies ignore it.
    pub fn hint_demand(&self, kernel: &str, queued: u64) {
        if let Ok(entry) = self.registry.require(kernel, DeviceType::Fpga) {
            self.router.hint_demand(entry.kernel_object, queued);
        }
    }

    // ---- introspection used by benches/examples ----

    pub fn setup_timing(&self) -> SetupTiming {
        self.setup
    }

    /// Pooled reconfiguration stats: the field-wise sum over every FPGA
    /// agent (identical to the single agent's stats at pool size 1).
    pub fn reconfig_stats(&self) -> ReconfigStats {
        let mut total = ReconfigStats::default();
        for agent in self.router.agents() {
            total.accumulate(&agent.reconfig_stats());
        }
        total
    }

    /// Per-agent reconfiguration stats, in pool order.
    pub fn reconfig_stats_per_agent(&self) -> Vec<ReconfigStats> {
        self.router.agents().map(|a| a.reconfig_stats()).collect()
    }

    /// Per-agent routing/dispatch accounting (dispatches, in-flight
    /// high-water, reconfig stats), in pool order.
    pub fn shard_stats(&self) -> Vec<ShardAgentReport> {
        self.router.report()
    }

    /// The FPGA dispatch router (pool membership, strategy, rollups).
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// The session's trace recorder, when tracing is on — the shared
    /// timeline that request spans, plan dispatches and device events
    /// (reconfigurations, kernel executions) all land on.
    pub fn trace(&self) -> Option<&crate::trace::TraceRecorder> {
        self.trace.as_ref()
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    pub fn placement(&self) -> &PlacementMap {
        &self.placement
    }

    pub fn registry(&self) -> &KernelRegistry {
        &self.registry
    }

    pub fn weights(&self) -> &WeightBank {
        &self.weights
    }

    pub fn cpu_agent(&self) -> &Arc<CpuAgent> {
        &self.cpu
    }

    /// First (or only) FPGA agent of the pool — the historical accessor;
    /// use [`Session::shard_stats`] / [`Session::router`] for the others.
    pub fn fpga_agent(&self) -> &Arc<FpgaAgent> {
        self.router.agent(0)
    }

    pub fn hsa_runtime(&self) -> &HsaRuntime {
        &self.runtime
    }

    pub fn queue(&self, device: DeviceType) -> Option<&Queue> {
        self.queues.get(&device)
    }

    /// Raw HSA dispatch, bypassing graph/executor overhead (Table II's
    /// "HSA Runtime" column).
    pub fn dispatch_raw(
        &self,
        device: DeviceType,
        kernel: &str,
        inputs: Vec<Tensor>,
    ) -> Result<Vec<Tensor>> {
        let entry = self.registry.require(kernel, device)?;
        let env = ExecEnv {
            runtime: &self.runtime,
            queues: &self.queues,
            router: Some(&self.router),
        };
        let (queue, _route) = env.route(device, entry.kernel_object)?;
        self.runtime.dispatch_sync(&queue, entry.kernel_object, inputs)
    }

    pub fn shutdown(&self) {
        self.runtime.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Built-in kernel registration
// ---------------------------------------------------------------------------

type NativeFn = Arc<dyn Fn(&[Tensor]) -> Result<Vec<Tensor>> + Send + Sync>;

fn native_fc() -> NativeFn {
    Arc::new(|ins| Ok(vec![crate::ops::fc_f32(&ins[0], &ins[1], &ins[2])?]))
}

fn native_fc_relu() -> NativeFn {
    Arc::new(|ins| Ok(vec![crate::ops::fc_relu_f32(&ins[0], &ins[1], &ins[2])?]))
}

fn native_conv_i16(w: Vec<i16>, f: usize, c: usize, kh: usize, kw: usize, shift: u32) -> NativeFn {
    Arc::new(move |ins| {
        Ok(vec![crate::ops::conv2d_fixed_i16(&ins[0], &w, f, c, kh, kw, shift)?])
    })
}

fn native_conv_i16_relu(
    w: Vec<i16>,
    f: usize,
    c: usize,
    kh: usize,
    kw: usize,
    shift: u32,
) -> NativeFn {
    Arc::new(move |ins| {
        Ok(vec![crate::ops::conv2d_fixed_i16_relu(&ins[0], &w, f, c, kh, kw, shift)?])
    })
}

fn native_conv_f32(w: Vec<f32>, f: usize, c: usize, kh: usize, kw: usize) -> NativeFn {
    Arc::new(move |ins| {
        Ok(vec![crate::ops::conv2d_fixed_f32(&ins[0], &w, f, c, kh, kw)?])
    })
}

fn native_conv_f32_relu(w: Vec<f32>, f: usize, c: usize, kh: usize, kw: usize) -> NativeFn {
    Arc::new(move |ins| {
        Ok(vec![crate::ops::conv2d_fixed_f32_relu(&ins[0], &w, f, c, kh, kw)?])
    })
}

fn native_fc_fixed(w: Vec<f32>, b: Vec<f32>, k: usize, n: usize) -> NativeFn {
    Arc::new(move |ins| {
        let wt = Tensor::from_f32(&[k, n], w.clone())?;
        let bt = Tensor::from_f32(&[n], b.clone())?;
        Ok(vec![crate::ops::fc_f32(&ins[0], &wt, &bt)?])
    })
}

fn native_fc_fixed_relu(w: Vec<f32>, b: Vec<f32>, k: usize, n: usize) -> NativeFn {
    Arc::new(move |ins| {
        let wt = Tensor::from_f32(&[k, n], w.clone())?;
        let bt = Tensor::from_f32(&[n], b.clone())?;
        Ok(vec![crate::ops::fc_relu_f32(&ins[0], &wt, &bt)?])
    })
}

/// Native full-CNN kernel (one dispatch per batch) — identical math to the
/// PJRT `mnist_cnn` module.
pub fn native_mnist_cnn(weights: &Arc<WeightBank>) -> NativeFn {
    let w = Arc::clone(weights);
    Arc::new(move |ins: &[Tensor]| {
        let x = &ins[0];
        let s = x.shape();
        if s.len() != 4 || s[1] != 1 || s[2] != 28 || s[3] != 28 {
            return Err(HsaError::KernelFailed(format!(
                "mnist_cnn wants (B,1,28,28), got {s:?}"
            )));
        }
        let b = s[0];
        let xd = x.as_f32()?;
        let mut logits = Vec::with_capacity(b * 10);
        for i in 0..b {
            let img = Tensor::from_f32(&[1, 28, 28], xd[i * 784..(i + 1) * 784].to_vec())?;
            let h = crate::ops::conv2d_fixed_f32(&img, &w.cnn_conv1, 2, 1, 3, 3)?;
            let h = crate::ops::relu_f32(&h)?;
            let h = crate::ops::maxpool2_f32(&h)?;
            let h = crate::ops::conv2d_fixed_f32(&h, &w.cnn_conv2, 4, 2, 5, 5)?;
            let h = crate::ops::relu_f32(&h)?;
            let h = crate::ops::maxpool2_f32(&h)?; // (4,4,4)
            let h = h.reshape(&[1, 64])?;
            let w1 = Tensor::from_f32(&[64, 32], w.cnn_fc1_w.clone())?;
            let b1 = Tensor::from_f32(&[32], w.cnn_fc1_b.clone())?;
            let h = crate::ops::fc_f32(&h, &w1, &b1)?;
            let h = crate::ops::relu_f32(&h)?;
            let w2 = Tensor::from_f32(&[32, 10], w.cnn_fc2_w.clone())?;
            let b2 = Tensor::from_f32(&[10], w.cnn_fc2_b.clone())?;
            let h = crate::ops::fc_f32(&h, &w2, &b2)?;
            logits.extend_from_slice(h.as_f32()?);
        }
        Ok(vec![Tensor::from_f32(&[b, 10], logits)?])
    })
}

fn register_cpu_kernels(
    cpu: &Arc<CpuAgent>,
    weights: &Arc<WeightBank>,
    registry: &mut KernelRegistry,
) {
    let shift = weights.conv_shift;
    let mut reg = |name: &str, kernel: CpuKernel| {
        let id = cpu.register_kernel(kernel);
        registry.register(name, DeviceType::Cpu, id);
    };

    reg(
        "fc",
        CpuKernel {
            name: "fc".into(),
            func: native_fc(),
            class: CpuKernelClass::FcF32,
            op_template: Some(RoleOp::FcF32 { m: 64, k: 64, n: 64 }),
        },
    );
    reg(
        "fc_barrier",
        CpuKernel {
            name: "fc_barrier".into(),
            func: native_fc(), // same math on a CPU
            class: CpuKernelClass::FcF32,
            op_template: Some(RoleOp::FcF32 { m: 64, k: 64, n: 64 }),
        },
    );
    reg(
        "conv5x5_i16",
        CpuKernel {
            name: "conv5x5_i16".into(),
            func: native_conv_i16(weights.conv5_w.clone(), 1, 1, 5, 5, shift),
            class: CpuKernelClass::ConvI16Large,
            op_template: Some(RoleOp::ConvI16 {
                cin: 1, h: 28, w: 28, kh: 5, kw: 5, filters: 1,
            }),
        },
    );
    reg(
        "conv3x3_i16",
        CpuKernel {
            name: "conv3x3_i16".into(),
            func: native_conv_i16(weights.conv3_w.clone(), 2, 1, 3, 3, shift),
            class: CpuKernelClass::ConvI16Small,
            op_template: Some(RoleOp::ConvI16 {
                cin: 1, h: 28, w: 28, kh: 3, kw: 3, filters: 2,
            }),
        },
    );
    reg(
        "relu",
        CpuKernel {
            name: "relu".into(),
            func: Arc::new(|ins| {
                Ok(vec![match ins[0].dtype() {
                    crate::tf::dtype::DType::I16 => crate::ops::relu_i16(&ins[0])?,
                    _ => crate::ops::relu_f32(&ins[0])?,
                }])
            }),
            class: CpuKernelClass::Memory,
            op_template: None,
        },
    );
    reg(
        "softmax",
        CpuKernel {
            name: "softmax".into(),
            func: Arc::new(|ins| Ok(vec![crate::ops::softmax_f32(&ins[0])?])),
            class: CpuKernelClass::Memory,
            op_template: None,
        },
    );
    reg(
        "maxpool2",
        CpuKernel {
            name: "maxpool2".into(),
            func: Arc::new(|ins| Ok(vec![crate::ops::maxpool2_f32(&ins[0])?])),
            class: CpuKernelClass::Memory,
            op_template: None,
        },
    );
    reg(
        "global_avgpool",
        CpuKernel {
            name: "global_avgpool".into(),
            func: Arc::new(|ins| Ok(vec![crate::ops::global_avgpool_f32(&ins[0])?])),
            class: CpuKernelClass::Memory,
            op_template: None,
        },
    );
    reg(
        "add",
        CpuKernel {
            name: "add".into(),
            func: Arc::new(|ins| Ok(vec![crate::ops::add_f32(&ins[0], &ins[1])?])),
            class: CpuKernelClass::Memory,
            op_template: None,
        },
    );
    reg(
        "quantize",
        CpuKernel {
            name: "quantize".into(),
            func: {
                let fb = shift;
                Arc::new(move |ins| Ok(vec![crate::ops::quantize_f32_to_i16(&ins[0], fb)?]))
            },
            class: CpuKernelClass::Memory,
            op_template: None,
        },
    );
    reg(
        "dequantize",
        CpuKernel {
            name: "dequantize".into(),
            func: {
                let fb = shift;
                Arc::new(move |ins| Ok(vec![crate::ops::dequantize_i16_to_f32(&ins[0], fb)?]))
            },
            class: CpuKernelClass::Memory,
            op_template: None,
        },
    );
    reg(
        "mnist_cnn",
        CpuKernel {
            name: "mnist_cnn".into(),
            func: native_mnist_cnn(weights),
            class: CpuKernelClass::FcF32,
            op_template: None,
        },
    );
    // ReLU-fused variants (the plan compiler's fusion pass dispatches
    // these instead of an op+relu pair whenever they are registered).
    reg(
        &fused_relu_name("fc"),
        CpuKernel {
            name: fused_relu_name("fc"),
            func: native_fc_relu(),
            class: CpuKernelClass::FcF32,
            op_template: Some(RoleOp::FcF32 { m: 64, k: 64, n: 64 }),
        },
    );
    reg(
        &fused_relu_name("fc_barrier"),
        CpuKernel {
            name: fused_relu_name("fc_barrier"),
            func: native_fc_relu(),
            class: CpuKernelClass::FcF32,
            op_template: Some(RoleOp::FcF32 { m: 64, k: 64, n: 64 }),
        },
    );
    reg(
        &fused_relu_name("conv5x5_i16"),
        CpuKernel {
            name: fused_relu_name("conv5x5_i16"),
            func: native_conv_i16_relu(weights.conv5_w.clone(), 1, 1, 5, 5, shift),
            class: CpuKernelClass::ConvI16Large,
            op_template: Some(RoleOp::ConvI16 {
                cin: 1, h: 28, w: 28, kh: 5, kw: 5, filters: 1,
            }),
        },
    );
    reg(
        &fused_relu_name("conv3x3_i16"),
        CpuKernel {
            name: fused_relu_name("conv3x3_i16"),
            func: native_conv_i16_relu(weights.conv3_w.clone(), 2, 1, 3, 3, shift),
            class: CpuKernelClass::ConvI16Small,
            op_template: Some(RoleOp::ConvI16 {
                cin: 1, h: 28, w: 28, kh: 3, kw: 3, filters: 2,
            }),
        },
    );
    // CNN layer kernels (fixed weights) for the layer-wise graph.
    reg(
        "convf32:cnn/conv1",
        CpuKernel {
            name: "convf32:cnn/conv1".into(),
            func: native_conv_f32(weights.cnn_conv1.clone(), 2, 1, 3, 3),
            class: CpuKernelClass::ConvI16Small,
            op_template: None,
        },
    );
    reg(
        "convf32:cnn/conv2",
        CpuKernel {
            name: "convf32:cnn/conv2".into(),
            func: native_conv_f32(weights.cnn_conv2.clone(), 4, 2, 5, 5),
            class: CpuKernelClass::ConvI16Large,
            op_template: None,
        },
    );
    reg(
        "fcfixed:cnn/fc1_w",
        CpuKernel {
            name: "fcfixed:cnn/fc1_w".into(),
            func: native_fc_fixed(weights.cnn_fc1_w.clone(), weights.cnn_fc1_b.clone(), 64, 32),
            class: CpuKernelClass::FcF32,
            op_template: Some(RoleOp::FcF32 { m: 1, k: 64, n: 32 }),
        },
    );
    reg(
        "fcfixed:cnn/fc2_w",
        CpuKernel {
            name: "fcfixed:cnn/fc2_w".into(),
            func: native_fc_fixed(weights.cnn_fc2_w.clone(), weights.cnn_fc2_b.clone(), 32, 10),
            class: CpuKernelClass::FcF32,
            op_template: Some(RoleOp::FcF32 { m: 1, k: 32, n: 10 }),
        },
    );
    // Fused variants of the CNN layers that are followed by ReLU in the
    // layer-wise MNIST graph.
    reg(
        &fused_relu_name("convf32:cnn/conv1"),
        CpuKernel {
            name: fused_relu_name("convf32:cnn/conv1"),
            func: native_conv_f32_relu(weights.cnn_conv1.clone(), 2, 1, 3, 3),
            class: CpuKernelClass::ConvI16Small,
            op_template: None,
        },
    );
    reg(
        &fused_relu_name("convf32:cnn/conv2"),
        CpuKernel {
            name: fused_relu_name("convf32:cnn/conv2"),
            func: native_conv_f32_relu(weights.cnn_conv2.clone(), 4, 2, 5, 5),
            class: CpuKernelClass::ConvI16Large,
            op_template: None,
        },
    );
    reg(
        &fused_relu_name("fcfixed:cnn/fc1_w"),
        CpuKernel {
            name: fused_relu_name("fcfixed:cnn/fc1_w"),
            func: native_fc_fixed_relu(weights.cnn_fc1_w.clone(), weights.cnn_fc1_b.clone(), 64, 32),
            class: CpuKernelClass::FcF32,
            op_template: Some(RoleOp::FcF32 { m: 1, k: 64, n: 32 }),
        },
    );
}

/// Register every FPGA role on **all** pool agents (shared kernel-object
/// ids — see [`FpgaPool::register_role`]) and in the kernel registry.
fn register_fpga_roles(
    fpga: &FpgaPool,
    weights: &Arc<WeightBank>,
    pjrt: Option<crate::runtime::pjrt::PjrtHandle>,
    store: Option<&ArtifactStore>,
    registry: &mut KernelRegistry,
) {
    let shift = weights.conv_shift;
    let paper = roles::paper_roles();
    // Bindings: PJRT module when available + signature matches, native
    // datapath math otherwise.
    let bind = |module: &str, native: NativeFn| -> ComputeBinding {
        match (&pjrt, store.and_then(|s| s.module(module).ok())) {
            (Some(handle), Some(meta)) => ComputeBinding::PjrtOrNative {
                handle: handle.clone(),
                module: module.to_string(),
                signature: meta.inputs.clone(),
                native,
            },
            _ => ComputeBinding::Native(native),
        }
    };

    let kernels: [(&str, &str, NativeFn); 4] = [
        ("fc", "role1_fc", native_fc()),
        ("fc_barrier", "role2_fc_barrier", native_fc()),
        (
            "conv5x5_i16",
            "role3_conv5x5",
            native_conv_i16(weights.conv5_w.clone(), 1, 1, 5, 5, shift),
        ),
        (
            "conv3x3_i16",
            "role4_conv3x3",
            native_conv_i16(weights.conv3_w.clone(), 2, 1, 3, 3, shift),
        ),
    ];
    for ((kernel_name, module, native), bitstream) in kernels.into_iter().zip(paper) {
        let id = fpga.register_role(bitstream, bind(module, native));
        registry.register(kernel_name, DeviceType::Fpga, id);
    }

    // ReLU-fused role variants (datapath + output clamp stage): the plan
    // compiler maps fused op+relu steps onto these so a fused step lives
    // in one PR region and costs one dispatch. No PJRT modules exist for
    // them, so they carry native numerics — and are therefore registered
    // only when the *base* role is native too: if the base executes a
    // PJRT-bound XLA module, a native fused variant could differ in f32
    // accumulation order from the unfused pair, and fusion must fall back
    // rather than change results with the fetch set.
    let fused_kernels: [(&str, &str, NativeFn); 4] = [
        ("fc", "role1_fc", native_fc_relu()),
        ("fc_barrier", "role2_fc_barrier", native_fc_relu()),
        (
            "conv5x5_i16",
            "role3_conv5x5",
            native_conv_i16_relu(weights.conv5_w.clone(), 1, 1, 5, 5, shift),
        ),
        (
            "conv3x3_i16",
            "role4_conv3x3",
            native_conv_i16_relu(weights.conv3_w.clone(), 2, 1, 3, 3, shift),
        ),
    ];
    for ((base, module, native), bitstream) in
        fused_kernels.into_iter().zip(roles::fused_paper_roles())
    {
        let base_is_pjrt_bound =
            pjrt.is_some() && store.is_some_and(|s| s.module(module).is_ok());
        if base_is_pjrt_bound {
            continue;
        }
        let id = fpga.register_role(bitstream, ComputeBinding::Native(native));
        registry.register(fused_relu_name(base), DeviceType::Fpga, id);
    }

    // CNN layers as weight-fixed roles (the paper's "fix layer weights to
    // have more efficient hardware" trade-off), plus the whole CNN as one
    // role for the serving path.
    let mk_role = |name: &str, op: RoleOp, macs: u32| {
        crate::fpga::bitstream::Bitstream::new(
            name,
            roles::ROLE_BITSTREAM_BYTES,
            crate::fpga::synthesis::estimate(&roles::role3_components()),
            crate::fpga::datapath::DatapathSpec {
                name: "cnn_layer",
                op,
                macs_per_cycle: macs,
                ii: 1,
                pipeline_depth: 32,
                burst_bytes: 4096,
                burst_overhead_cycles: 8,
                barriers_per_pass: 0,
                barrier_stall_cycles: 0,
                clock_mhz: roles::PL_CLOCK_MHZ,
            },
        )
    };

    let conv1 = mk_role(
        "cnn_conv1",
        RoleOp::ConvI16 { cin: 1, h: 28, w: 28, kh: 3, kw: 3, filters: 2 },
        18,
    );
    let id = fpga.register_role(conv1, ComputeBinding::Native(native_conv_f32(weights.cnn_conv1.clone(), 2, 1, 3, 3)));
    registry.register("convf32:cnn/conv1", DeviceType::Fpga, id);

    let conv2 = mk_role(
        "cnn_conv2",
        RoleOp::ConvI16 { cin: 2, h: 13, w: 13, kh: 5, kw: 5, filters: 4 },
        25,
    );
    let id = fpga.register_role(conv2, ComputeBinding::Native(native_conv_f32(weights.cnn_conv2.clone(), 4, 2, 5, 5)));
    registry.register("convf32:cnn/conv2", DeviceType::Fpga, id);

    let fc1 = mk_role("cnn_fc1", RoleOp::FcF32 { m: 1, k: 64, n: 32 }, 4);
    let id = fpga.register_role(
        fc1,
        ComputeBinding::Native(native_fc_fixed(weights.cnn_fc1_w.clone(), weights.cnn_fc1_b.clone(), 64, 32)),
    );
    registry.register("fcfixed:cnn/fc1_w", DeviceType::Fpga, id);

    let fc2 = mk_role("cnn_fc2", RoleOp::FcF32 { m: 1, k: 32, n: 10 }, 4);
    let id = fpga.register_role(
        fc2,
        ComputeBinding::Native(native_fc_fixed(weights.cnn_fc2_w.clone(), weights.cnn_fc2_b.clone(), 32, 10)),
    );
    registry.register("fcfixed:cnn/fc2_w", DeviceType::Fpga, id);

    // Fused variants of the ReLU-followed CNN layers.
    let conv1_relu = mk_role(
        "cnn_conv1_relu",
        RoleOp::ConvI16 { cin: 1, h: 28, w: 28, kh: 3, kw: 3, filters: 2 },
        18,
    );
    let id = fpga.register_role(
        conv1_relu,
        ComputeBinding::Native(native_conv_f32_relu(weights.cnn_conv1.clone(), 2, 1, 3, 3)),
    );
    registry.register(fused_relu_name("convf32:cnn/conv1"), DeviceType::Fpga, id);

    let conv2_relu = mk_role(
        "cnn_conv2_relu",
        RoleOp::ConvI16 { cin: 2, h: 13, w: 13, kh: 5, kw: 5, filters: 4 },
        25,
    );
    let id = fpga.register_role(
        conv2_relu,
        ComputeBinding::Native(native_conv_f32_relu(weights.cnn_conv2.clone(), 4, 2, 5, 5)),
    );
    registry.register(fused_relu_name("convf32:cnn/conv2"), DeviceType::Fpga, id);

    let fc1_relu = mk_role("cnn_fc1_relu", RoleOp::FcF32 { m: 1, k: 64, n: 32 }, 4);
    let id = fpga.register_role(
        fc1_relu,
        ComputeBinding::Native(native_fc_fixed_relu(
            weights.cnn_fc1_w.clone(),
            weights.cnn_fc1_b.clone(),
            64,
            32,
        )),
    );
    registry.register(fused_relu_name("fcfixed:cnn/fc1_w"), DeviceType::Fpga, id);

    let full = mk_role(
        "cnn_full",
        RoleOp::Stream { elements: 32 * 784, ops_per_element: 60 },
        32,
    );
    let native = native_mnist_cnn(weights);
    let id = fpga.register_role(full, bind("mnist_cnn", native));
    registry.register("mnist_cnn", DeviceType::Fpga, id);
}

/// Register kernels whose identity depends on the *graph* rather than on
/// the fixed paper roles. Imported ONNX graphs carry attribute-bearing ops
/// whose attributes are baked into the kernel name (`conv2d:p{pad}`,
/// `concat:a{axis}`), so the set of kernels to register is only known once
/// the finalized graph is in hand. Each distinct conv padding gets a CPU
/// kernel, an FPGA role variant and both fused `+relu` forms — imported
/// graphs place onto PR regions exactly like the built-in roles. Concat is
/// a pure data-movement op and registers CPU-only.
fn register_graph_kernels(
    graph: &Graph,
    cpu: &Arc<CpuAgent>,
    fpga: &FpgaPool,
    registry: &mut KernelRegistry,
) {
    use std::collections::{BTreeMap, BTreeSet};
    // The role's nominal workload (cost model only, not numerics) comes
    // from the first conv in the graph using that padding.
    let mut conv_pads: BTreeMap<usize, RoleOp> = BTreeMap::new();
    let mut concat_axes: BTreeSet<usize> = BTreeSet::new();
    for node in graph.nodes() {
        match &node.op {
            OpKind::Conv2dF32 { pad } => {
                conv_pads.entry(*pad).or_insert_with(|| {
                    let xs = &graph.node(node.inputs[0]).out_shape;
                    let ws = &graph.node(node.inputs[1]).out_shape;
                    RoleOp::ConvI16 {
                        cin: xs[0],
                        h: xs[1] + 2 * pad,
                        w: xs[2] + 2 * pad,
                        kh: ws[2],
                        kw: ws[3],
                        filters: ws[0],
                    }
                });
            }
            OpKind::Concat { axis } => {
                concat_axes.insert(*axis);
            }
            _ => {}
        }
    }

    for (pad, op_template) in conv_pads {
        let mk_bitstream = |name: String| {
            crate::fpga::bitstream::Bitstream::new(
                name,
                roles::ROLE_BITSTREAM_BYTES,
                crate::fpga::synthesis::estimate(&roles::role3_components()),
                crate::fpga::datapath::DatapathSpec {
                    name: "conv2d",
                    op: op_template,
                    macs_per_cycle: 16,
                    ii: 1,
                    pipeline_depth: 32,
                    burst_bytes: 4096,
                    burst_overhead_cycles: 8,
                    barriers_per_pass: 0,
                    barrier_stall_cycles: 0,
                    clock_mhz: roles::PL_CLOCK_MHZ,
                },
            )
        };
        let base = format!("conv2d:p{pad}");
        let native: NativeFn = Arc::new(move |ins| {
            Ok(vec![crate::ops::conv2d_f32(&ins[0], &ins[1], &ins[2], pad)?])
        });
        let native_relu: NativeFn = Arc::new(move |ins| {
            Ok(vec![crate::ops::conv2d_f32_relu(&ins[0], &ins[1], &ins[2], pad)?])
        });

        let id = cpu.register_kernel(CpuKernel {
            name: base.clone(),
            func: Arc::clone(&native),
            class: CpuKernelClass::ConvI16Large,
            op_template: Some(op_template),
        });
        registry.register(&base, DeviceType::Cpu, id);
        let id = cpu.register_kernel(CpuKernel {
            name: fused_relu_name(&base),
            func: Arc::clone(&native_relu),
            class: CpuKernelClass::ConvI16Large,
            op_template: Some(op_template),
        });
        registry.register(fused_relu_name(&base), DeviceType::Cpu, id);

        let id = fpga.register_role(
            mk_bitstream(format!("conv2d_p{pad}")),
            ComputeBinding::Native(native),
        );
        registry.register(&base, DeviceType::Fpga, id);
        let id = fpga.register_role(
            mk_bitstream(format!("conv2d_p{pad}_relu")),
            ComputeBinding::Native(native_relu),
        );
        registry.register(fused_relu_name(&base), DeviceType::Fpga, id);
    }

    for axis in concat_axes {
        let name = format!("concat:a{axis}");
        let id = cpu.register_kernel(CpuKernel {
            name: name.clone(),
            func: Arc::new(move |ins| {
                let refs: Vec<&Tensor> = ins.iter().collect();
                Ok(vec![crate::ops::concat_f32(&refs, axis)?])
            }),
            class: CpuKernelClass::Memory,
            op_template: None,
        });
        registry.register(&name, DeviceType::Cpu, id);
    }
}

/// Wait helper re-exported for examples.
pub const DISPATCH_TIMEOUT: Duration = crate::hsa::runtime::DISPATCH_TIMEOUT;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tf::dtype::DType;
    use crate::tf::graph::OpKind;

    fn fc_graph() -> Graph {
        let mut g = Graph::new();
        let x = g.placeholder("x", &[4, 8], DType::F32).unwrap();
        let w = g.constant("w", Tensor::from_f32(&[8, 2], vec![0.5; 16]).unwrap()).unwrap();
        let b = g.constant("b", Tensor::from_f32(&[2], vec![1.0, -1.0]).unwrap()).unwrap();
        let y = g.add("y", OpKind::FullyConnected, &[x, w, b]).unwrap();
        g.add("out", OpKind::Relu, &[y]).unwrap();
        g
    }

    #[test]
    fn session_runs_fc_graph_native() {
        let sess = Session::new(fc_graph(), SessionOptions::native_only()).unwrap();
        let x = Tensor::from_f32(&[4, 8], vec![1.0; 32]).unwrap();
        let out = sess.run(&[("x", x)], &["out"]).unwrap();
        // 8 * 0.5 = 4 (+1 / -1) -> [5, 3] per row, relu keeps both.
        assert_eq!(out[0].shape(), &[4, 2]);
        for row in out[0].as_f32().unwrap().chunks(2) {
            assert_eq!(row, &[5.0, 3.0]);
        }
        sess.shutdown();
    }

    #[test]
    fn fpga_and_cpu_agree_on_fc() {
        let sess_fpga = Session::new(fc_graph(), SessionOptions::native_only()).unwrap();
        let sess_cpu = Session::new(fc_graph(), SessionOptions::cpu_baseline()).unwrap();
        let x = Tensor::from_f32(&[4, 8], (0..32).map(|v| v as f32 * 0.1).collect()).unwrap();
        let a = sess_fpga.run(&[("x", x.clone())], &["out"]).unwrap();
        let b = sess_cpu.run(&[("x", x)], &["out"]).unwrap();
        assert_eq!(a[0], b[0]);
        // And the FPGA session actually used the FPGA.
        assert!(sess_fpga.reconfig_stats().dispatches > 0);
        assert_eq!(sess_cpu.reconfig_stats().dispatches, 0);
        sess_fpga.shutdown();
        sess_cpu.shutdown();
    }

    #[test]
    fn conv_roles_reconfigure_and_match_cpu() {
        let mut g = Graph::new();
        let x = g.placeholder("x", &[1, 28, 28], DType::I16).unwrap();
        let c5 = g.add("c5", OpKind::Conv5x5I16, &[x]).unwrap();
        let _ = c5;
        g.add("c3", OpKind::Conv3x3I16, &[x]).unwrap();
        let sess = Session::new(g.clone(), SessionOptions::native_only()).unwrap();
        let cpu_sess = Session::new(g, SessionOptions::cpu_baseline()).unwrap();
        let mut vals = vec![0i16; 784];
        let mut rng = Rng::new(3);
        rng.fill_i16(&mut vals, -256, 255);
        let x = Tensor::from_i16(&[1, 28, 28], vals).unwrap();
        let a = sess.run(&[("x", x.clone())], &["c5", "c3"]).unwrap();
        let b = cpu_sess.run(&[("x", x)], &["c5", "c3"]).unwrap();
        assert_eq!(a[0], b[0]);
        assert_eq!(a[1], b[1]);
        let s = sess.reconfig_stats();
        assert_eq!(s.misses, 2, "two roles loaded");
        sess.shutdown();
        cpu_sess.shutdown();
    }

    #[test]
    fn setup_timing_recorded() {
        let sess = Session::new(fc_graph(), SessionOptions::native_only()).unwrap();
        assert!(sess.setup_timing().total_us > 0);
        sess.shutdown();
    }

    #[test]
    fn run_async_fast_path_matches_sync_run() {
        let sess = Session::new(fc_graph(), SessionOptions::native_only()).unwrap();
        let x = Tensor::from_f32(&[4, 8], (0..32).map(|v| v as f32 * 0.25).collect()).unwrap();
        // "y" is a device-placed FC fed only by structural ops → fast path.
        let pending = sess.run_async(&[("x", x.clone())], &["y"]).unwrap();
        assert!(pending.signal().is_some(), "expected the in-flight fast path");
        let async_out = pending.wait(Some(Duration::from_secs(30))).unwrap();
        let sync_out = sess.run(&[("x", x)], &["y"]).unwrap();
        assert_eq!(async_out[0], sync_out[0]);
        sess.shutdown();
    }

    #[test]
    fn run_async_falls_back_for_chained_device_ops() {
        let sess = Session::new(fc_graph(), SessionOptions::native_only()).unwrap();
        let x = Tensor::from_f32(&[4, 8], vec![1.0; 32]).unwrap();
        // "out" = Relu(y) consumes another device op → synchronous fallback.
        let pending = sess.run_async(&[("x", x.clone())], &["out"]).unwrap();
        assert!(pending.signal().is_none(), "chained graph should fall back");
        assert!(pending.is_done());
        let outs = pending.wait(None).unwrap();
        assert_eq!(outs[0], sess.run(&[("x", x)], &["out"]).unwrap()[0]);
        sess.shutdown();
    }

    #[test]
    fn run_async_many_in_flight_with_worker_pool() {
        let opts = SessionOptions {
            dispatch_workers: 4,
            ..SessionOptions::native_only()
        };
        let sess = Session::new(fc_graph(), opts).unwrap();
        let pendings: Vec<PendingRun> = (0..8)
            .map(|i| {
                let x = Tensor::from_f32(&[4, 8], vec![i as f32; 32]).unwrap();
                sess.run_async(&[("x", x)], &["y"]).unwrap()
            })
            .collect();
        for (i, p) in pendings.into_iter().enumerate() {
            let out = p.wait(Some(Duration::from_secs(30))).unwrap();
            // y = sum(x_row) * 0.5 + bias: row value i*8*0.5 = 4i, +1 / -1.
            let want = [4.0 * i as f32 + 1.0, 4.0 * i as f32 - 1.0];
            for row in out[0].as_f32().unwrap().chunks(2) {
                assert_eq!(row, &want, "request {i} got another batch's tensor");
            }
        }
        sess.shutdown();
    }

    #[test]
    fn fused_plan_issues_strictly_fewer_dispatches_than_interpreter() {
        let sess = Session::new(fc_graph(), SessionOptions::native_only()).unwrap();
        let x = Tensor::from_f32(&[4, 8], (0..32).map(|v| v as f32 * 0.3 - 4.0).collect())
            .unwrap();
        let (outs, plan_stats) = sess.run_with_stats(&[("x", x.clone())], &["out"]).unwrap();
        let (ref_outs, interp_stats) = sess.run_interpreted(&[("x", x)], &["out"]).unwrap();
        assert_eq!(outs[0], ref_outs[0], "fused replay must be bitwise identical");
        assert_eq!(plan_stats.dispatches, 1, "FC+Relu collapses into one dispatch");
        assert_eq!(plan_stats.fused_dispatches, 1);
        assert_eq!(interp_stats.dispatches, 2, "the interpreter never fuses");
        assert!(plan_stats.dispatches < interp_stats.dispatches);
        sess.shutdown();
    }

    #[test]
    fn graph_driven_conv2d_kernels_register_fuse_and_place_on_fpga() {
        // The ONNX-import graph shape: attribute-bearing ops whose kernels
        // (`conv2d:p1`, `concat:a0`) exist only because the graph demands
        // them. Conv+ReLU must fuse, the conv must land on a PR region,
        // and plan replay must match the interpreter bitwise.
        let mut g = Graph::new();
        let x = g.placeholder("x", &[1, 6, 6], DType::F32).unwrap();
        let w = g
            .constant(
                "w",
                Tensor::from_f32(&[2, 1, 3, 3], (0..18).map(|v| v as f32 * 0.1 - 0.8).collect())
                    .unwrap(),
            )
            .unwrap();
        let b = g.constant("b", Tensor::from_f32(&[2], vec![0.1, -0.2]).unwrap()).unwrap();
        let c = g.add("c", OpKind::Conv2dF32 { pad: 1 }, &[x, w, b]).unwrap();
        let r = g.add("r", OpKind::Relu, &[c]).unwrap();
        let gap = g.add("gap", OpKind::GlobalAvgPool, &[r]).unwrap();
        g.add("out", OpKind::Concat { axis: 0 }, &[gap, gap]).unwrap();

        let sess = Session::new(g, SessionOptions::native_only()).unwrap();
        let x = Tensor::from_f32(&[1, 6, 6], (0..36).map(|v| v as f32 * 0.21 - 3.5).collect())
            .unwrap();
        let (outs, plan_stats) = sess.run_with_stats(&[("x", x.clone())], &["out"]).unwrap();
        let (ref_outs, _) = sess.run_interpreted(&[("x", x)], &["out"]).unwrap();
        assert_eq!(outs[0], ref_outs[0], "plan replay must be bitwise identical");
        assert_eq!(outs[0].shape(), &[4, 1, 1]);
        assert!(plan_stats.fused_dispatches >= 1, "conv2d+relu fused: {plan_stats:?}");
        assert!(
            plan_stats.dispatches_by_device.get(&DeviceType::Fpga).copied().unwrap_or(0) >= 1,
            "conv2d placed on a PR region: {:?}",
            plan_stats.dispatches_by_device
        );
        sess.shutdown();
    }

    #[test]
    fn plan_cache_hits_on_repeat_and_misses_on_new_fetch_set() {
        let sess = Session::new(fc_graph(), SessionOptions::native_only()).unwrap();
        let x = Tensor::from_f32(&[4, 8], vec![1.0; 32]).unwrap();
        sess.run(&[("x", x.clone())], &["out"]).unwrap();
        sess.run(&[("x", x.clone())], &["out"]).unwrap();
        let s = sess.plan_cache_stats();
        assert_eq!((s.entries, s.compiles, s.hits), (1, 1, 1), "{s:?}");
        sess.run(&[("x", x)], &["y"]).unwrap();
        let s = sess.plan_cache_stats();
        assert_eq!((s.entries, s.compiles, s.hits), (2, 2, 1), "{s:?}");
        sess.shutdown();
    }

    #[test]
    fn plan_cache_invalidates_on_feed_shape_change() {
        let sess = Session::new(fc_graph(), SessionOptions::native_only()).unwrap();
        let good = Tensor::from_f32(&[4, 8], vec![0.5; 32]).unwrap();
        let want = sess.run(&[("x", good.clone())], &["out"]).unwrap();
        // A differently-shaped feed must not replay the cached plan: it is
        // rejected before the cache, so no dead entry is ever inserted.
        let bad = Tensor::zeros(&[8, 4], DType::F32);
        let err = sess.run(&[("x", bad)], &["out"]).unwrap_err();
        assert!(err.to_string().contains("expected"), "{err}");
        assert_eq!(sess.plan_cache_stats().entries, 1, "bad feed must not pollute");
        // The original entry is untouched and still replays correctly.
        let again = sess.run(&[("x", good)], &["out"]).unwrap();
        assert_eq!(want[0], again[0]);
        assert!(sess.plan_cache_stats().hits >= 1);
        sess.shutdown();
    }

    #[test]
    fn const_only_subgraph_folds_at_session_compile_time() {
        let mut g = Graph::new();
        let x = g.placeholder("x", &[2, 2], DType::F32).unwrap();
        let w = g
            .constant("w", Tensor::from_f32(&[2, 2], vec![-1.0, 2.0, -3.0, 4.0]).unwrap())
            .unwrap();
        let rw = g.add("rw", OpKind::Relu, &[w]).unwrap();
        g.add("out", OpKind::Add, &[x, rw]).unwrap();
        let sess = Session::new(g, SessionOptions::native_only()).unwrap();
        let x = Tensor::from_f32(&[2, 2], vec![1.0; 4]).unwrap();
        let (outs, plan_stats) = sess.run_with_stats(&[("x", x.clone())], &["out"]).unwrap();
        assert_eq!(outs[0].as_f32().unwrap(), &[1.0, 3.0, 1.0, 5.0]);
        assert_eq!(plan_stats.dispatches, 1, "relu(const) was folded at compile");
        let (_, interp_stats) = sess.run_interpreted(&[("x", x)], &["out"]).unwrap();
        assert_eq!(interp_stats.dispatches, 2);
        sess.shutdown();
    }

    #[test]
    fn pooled_session_matches_single_agent_bitwise() {
        let x = Tensor::from_f32(&[4, 8], (0..32).map(|v| v as f32 * 0.17 - 2.0).collect())
            .unwrap();
        let single = Session::new(fc_graph(), SessionOptions::native_only()).unwrap();
        let want = single.run(&[("x", x.clone())], &["out"]).unwrap();
        for strategy in ShardStrategy::ALL {
            let opts = SessionOptions {
                fpga_pool: 2,
                shard_strategy: strategy,
                ..SessionOptions::native_only()
            };
            let pooled = Session::new(fc_graph(), opts).unwrap();
            let got = pooled.run(&[("x", x.clone())], &["out"]).unwrap();
            assert_eq!(want[0], got[0], "pool-2 {strategy:?} diverged from single");
            pooled.shutdown();
        }
        single.shutdown();
    }

    #[test]
    fn round_robin_pool_spreads_dispatches_across_agents() {
        let opts = SessionOptions {
            fpga_pool: 2,
            shard_strategy: ShardStrategy::RoundRobin,
            ..SessionOptions::native_only()
        };
        let sess = Session::new(fc_graph(), opts).unwrap();
        let x = Tensor::from_f32(&[4, 8], vec![0.5; 32]).unwrap();
        for _ in 0..4 {
            sess.run(&[("x", x.clone())], &["out"]).unwrap();
        }
        let per_agent = sess.reconfig_stats_per_agent();
        assert_eq!(per_agent.len(), 2);
        assert_eq!(per_agent[0].dispatches, 2, "round robin: half each");
        assert_eq!(per_agent[1].dispatches, 2);
        let rollup = sess.reconfig_stats();
        assert_eq!(rollup.dispatches, 4, "rollup sums the pool");
        // Each agent paid its own cold reconfiguration.
        assert_eq!(rollup.misses, 2);
        let shard = sess.shard_stats();
        assert_eq!(shard.len(), 2);
        assert_eq!(shard[0].agent, "ultra96-pl-0");
        assert_eq!(shard[0].dispatches + shard[1].dispatches, 4);
        assert_eq!(sess.router().rollup().inflight, 0, "all retired");
        sess.shutdown();
    }

    #[test]
    fn kernel_affinity_pool_avoids_reconfig_churn() {
        // Two FPGA kernels, one region per agent, pool of 2: affinity
        // settles each kernel on its own agent, so after the two cold
        // loads every dispatch is a residency hit. (A single agent with
        // one region would miss on every alternation.)
        let mut g = Graph::new();
        let x = g.placeholder("x", &[1, 28, 28], DType::I16).unwrap();
        g.add("c5", OpKind::Conv5x5I16, &[x]).unwrap();
        g.add("c3", OpKind::Conv3x3I16, &[x]).unwrap();
        let opts = SessionOptions {
            fpga_pool: 2,
            num_regions: 1,
            shard_strategy: ShardStrategy::KernelAffinity,
            ..SessionOptions::native_only()
        };
        let sess = Session::new(g, opts).unwrap();
        let mut vals = vec![0i16; 784];
        let mut rng = Rng::new(5);
        rng.fill_i16(&mut vals, -256, 255);
        let x = Tensor::from_i16(&[1, 28, 28], vals).unwrap();
        for _ in 0..5 {
            sess.run(&[("x", x.clone())], &["c5", "c3"]).unwrap();
        }
        let s = sess.reconfig_stats();
        assert_eq!(s.dispatches, 10);
        assert_eq!(s.misses, 2, "one cold load per kernel, zero thrash");
        assert_eq!(s.evictions, 0);
        sess.shutdown();
    }

    #[test]
    fn run_async_shards_across_pool() {
        let opts = SessionOptions {
            fpga_pool: 2,
            shard_strategy: ShardStrategy::RoundRobin,
            ..SessionOptions::native_only()
        };
        let sess = Session::new(fc_graph(), opts).unwrap();
        let pendings: Vec<PendingRun> = (0..4)
            .map(|i| {
                let x = Tensor::from_f32(&[4, 8], vec![i as f32; 32]).unwrap();
                sess.run_async(&[("x", x)], &["y"]).unwrap()
            })
            .collect();
        for (i, p) in pendings.into_iter().enumerate() {
            let out = p.wait(Some(Duration::from_secs(30))).unwrap();
            let want = [4.0 * i as f32 + 1.0, 4.0 * i as f32 - 1.0];
            for row in out[0].as_f32().unwrap().chunks(2) {
                assert_eq!(row, &want, "request {i} crossed agents");
            }
        }
        let per_agent = sess.reconfig_stats_per_agent();
        assert_eq!(per_agent[0].dispatches, 2);
        assert_eq!(per_agent[1].dispatches, 2);
        assert_eq!(sess.router().rollup().inflight, 0);
        sess.shutdown();
    }

    #[test]
    fn dispatch_raw_bypasses_executor() {
        let sess = Session::new(fc_graph(), SessionOptions::native_only()).unwrap();
        let x = Tensor::from_f32(&[2, 3], vec![1.0; 6]).unwrap();
        let w = Tensor::from_f32(&[3, 2], vec![1.0; 6]).unwrap();
        let b = Tensor::from_f32(&[2], vec![0.0; 2]).unwrap();
        let out = sess.dispatch_raw(DeviceType::Cpu, "fc", vec![x, w, b]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[3.0, 3.0, 3.0, 3.0]);
        sess.shutdown();
    }
}
