//! Structured event tracing with Chrome-trace (about://tracing, Perfetto)
//! JSON export — reconfigurations, dispatches and kernel executions become
//! visually inspectable timelines.

pub mod recorder;

pub use recorder::{EventKind, TraceRecorder};
