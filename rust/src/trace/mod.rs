//! Structured event tracing with Chrome-trace (about://tracing, Perfetto)
//! JSON export — reconfigurations, dispatches and kernel executions become
//! visually inspectable timelines.
//!
//! Wire a [`TraceRecorder`] into `SessionOptions::trace` and the FPGA
//! agent emits one event per partial reconfiguration
//! ([`EventKind::Reconfig`]) and per kernel execution
//! ([`EventKind::KernelExec`]) onto the `fpga-pl` track, with the PR
//! region as the lane — so an async serving run renders as the familiar
//! "staircase" of overlapping batches, and an eviction storm is visible
//! as a wall of reconfig blocks. Export with
//! `TraceRecorder::to_chrome_trace` (or `write_to`) and load the file in
//! Perfetto.
//!
//! Request-scoped spans ride the same recorder: the HTTP frontend mints a
//! [`SpanCtx`] per request and the pipeline stages record their slice of
//! the latency onto a `req:<id>` track, so requests and devices share one
//! timeline (see [`span`]).
//!
//! Recording is lock-light (one mutex around a bounded ring) and cheap
//! enough to leave on in the serving path as an always-on flight
//! recorder: the ring caps memory, a dropped counter accounts for evicted
//! events, and `TraceRecorder::to_chrome_trace_since` exports a recent
//! window for `GET /v1/debug/trace?last_ms=N`.

pub mod recorder;
pub mod span;

pub use recorder::{EventKind, TraceRecorder};
pub use span::{SpanCtx, Stage};
