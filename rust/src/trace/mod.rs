//! Structured event tracing with Chrome-trace (about://tracing, Perfetto)
//! JSON export — reconfigurations, dispatches and kernel executions become
//! visually inspectable timelines.
//!
//! Wire a [`TraceRecorder`] into `SessionOptions::trace` and the FPGA
//! agent emits one event per partial reconfiguration
//! ([`EventKind::Reconfig`]) and per kernel execution
//! ([`EventKind::KernelExec`]) onto the `fpga-pl` track, with the PR
//! region as the lane — so an async serving run renders as the familiar
//! "staircase" of overlapping batches, and an eviction storm is visible
//! as a wall of reconfig blocks. Export with
//! `TraceRecorder::to_chrome_trace` (or `write_to`) and load the file in
//! Perfetto.
//!
//! Recording is lock-light (one mutex around an append-only event vec)
//! and cheap enough to leave on in the serving path; it is opt-in per
//! session regardless.

pub mod recorder;

pub use recorder::{EventKind, TraceRecorder};
