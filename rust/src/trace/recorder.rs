//! The trace recorder: thread-safe event sink + Chrome-trace JSON export.
//!
//! Events use the Trace Event Format's complete events (`"ph":"X"`): a
//! name, a category, a start timestamp (µs) and a duration. Tracks map to
//! the simulated devices ("pid" = device, "tid" = region/queue), so a
//! reconfiguration appears as a block on its PR region's track. Request
//! spans land on per-request tracks (`req:<id>`) alongside the device
//! lanes, so Perfetto shows each request aligned with the hardware
//! timeline it rode on.
//!
//! The recorder doubles as an always-on flight recorder: storage is a
//! bounded ring (capacity fixed at construction), so it can stay enabled
//! under serving load indefinitely — the oldest events fall off the back
//! and a dropped counter records how many did. Time-windowed export
//! ([`TraceRecorder::to_chrome_trace_since`]) backs the
//! `GET /v1/debug/trace?last_ms=N` endpoint.

use std::collections::VecDeque;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Default ring capacity. At the ~6 events a traced request emits, this
/// holds the last ~10k requests — hours of low-qps serving, minutes of a
/// load test — in a few MB.
pub const DEFAULT_CAPACITY: usize = 65_536;

/// Event categories.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    Dispatch,
    Reconfig,
    KernelExec,
    Barrier,
    Custom,
}

impl EventKind {
    fn category(self) -> &'static str {
        match self {
            EventKind::Dispatch => "dispatch",
            EventKind::Reconfig => "reconfig",
            EventKind::KernelExec => "kernel",
            EventKind::Barrier => "barrier",
            EventKind::Custom => "custom",
        }
    }
}

#[derive(Debug, Clone)]
struct Event {
    name: String,
    kind: EventKind,
    track: String,
    lane: u32,
    start_us: u64,
    dur_us: u64,
}

/// Cloneable, thread-safe recorder.
#[derive(Debug, Clone)]
pub struct TraceRecorder {
    inner: Arc<Inner>,
}

#[derive(Debug)]
struct Inner {
    epoch: Instant,
    capacity: usize,
    events: Mutex<VecDeque<Event>>,
    dropped: AtomicU64,
}

impl Default for TraceRecorder {
    fn default() -> Self {
        TraceRecorder::new()
    }
}

impl TraceRecorder {
    pub fn new() -> TraceRecorder {
        TraceRecorder::with_capacity(DEFAULT_CAPACITY)
    }

    /// Recorder whose ring holds at most `capacity` events (min 1). Once
    /// full, each new event evicts the oldest and bumps the dropped
    /// counter.
    pub fn with_capacity(capacity: usize) -> TraceRecorder {
        let capacity = capacity.max(1);
        TraceRecorder {
            inner: Arc::new(Inner {
                epoch: Instant::now(),
                capacity,
                events: Mutex::new(VecDeque::with_capacity(capacity.min(1024))),
                dropped: AtomicU64::new(0),
            }),
        }
    }

    /// Current timestamp in µs since the recorder's epoch.
    pub fn now_us(&self) -> u64 {
        self.inner.epoch.elapsed().as_micros() as u64
    }

    /// Ring capacity (events retained before the oldest are evicted).
    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }

    /// Events evicted from the ring since construction.
    pub fn dropped(&self) -> u64 {
        self.inner.dropped.load(Ordering::Relaxed)
    }

    /// Record a complete event with explicit timing.
    pub fn record(
        &self,
        kind: EventKind,
        name: impl Into<String>,
        track: impl Into<String>,
        lane: u32,
        start_us: u64,
        dur_us: u64,
    ) {
        let mut events = self.inner.events.lock().unwrap();
        if events.len() >= self.inner.capacity {
            events.pop_front();
            self.inner.dropped.fetch_add(1, Ordering::Relaxed);
        }
        events.push_back(Event {
            name: name.into(),
            kind,
            track: track.into(),
            lane,
            start_us,
            dur_us,
        });
    }

    /// Record an event that started `dur_us` ago and ends now.
    pub fn record_ending_now(
        &self,
        kind: EventKind,
        name: impl Into<String>,
        track: impl Into<String>,
        lane: u32,
        dur_us: u64,
    ) {
        let end = self.now_us();
        self.record(kind, name, track, lane, end.saturating_sub(dur_us), dur_us);
    }

    pub fn len(&self) -> usize {
        self.inner.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Export as Chrome Trace Event Format JSON.
    pub fn to_chrome_trace(&self) -> String {
        self.to_chrome_trace_since(0)
    }

    /// Chrome-trace export restricted to events still running at or after
    /// `cutoff_us` (recorder-epoch µs): an event is kept when
    /// `start_us + dur_us >= cutoff_us`. Track pids stay stable within one
    /// export (sorted track order), and metadata is only emitted for
    /// tracks that survive the window.
    pub fn to_chrome_trace_since(&self, cutoff_us: u64) -> String {
        let events = self.inner.events.lock().unwrap();
        let window: Vec<&Event> = events
            .iter()
            .filter(|e| e.start_us.saturating_add(e.dur_us) >= cutoff_us)
            .collect();
        // Stable pid mapping per track name.
        let mut tracks: Vec<&str> = window.iter().map(|e| e.track.as_str()).collect();
        tracks.sort();
        tracks.dedup();
        let pid_of = |t: &str| tracks.iter().position(|x| *x == t).unwrap() + 1;

        let mut out = String::from("{\"traceEvents\":[");
        for (i, t) in tracks.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "{{\"ph\":\"M\",\"pid\":{},\"name\":\"process_name\",\"args\":{{\"name\":{}}}}}",
                i + 1,
                crate::util::json::Json::Str(t.to_string())
            );
        }
        for e in &window {
            let _ = write!(
                out,
                ",{{\"ph\":\"X\",\"pid\":{},\"tid\":{},\"ts\":{},\"dur\":{},\"cat\":\"{}\",\"name\":{}}}",
                pid_of(&e.track),
                e.lane,
                e.start_us,
                e.dur_us,
                e.kind.category(),
                crate::util::json::Json::Str(e.name.clone())
            );
        }
        out.push_str("]}");
        out
    }

    /// Write the trace to a file.
    pub fn write_to(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_chrome_trace())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn records_and_counts() {
        let tr = TraceRecorder::new();
        assert!(tr.is_empty());
        tr.record(EventKind::Dispatch, "fc", "fpga", 0, 10, 5);
        tr.record(EventKind::Reconfig, "role3", "fpga", 1, 15, 7425);
        assert_eq!(tr.len(), 2);
        assert_eq!(tr.dropped(), 0);
    }

    #[test]
    fn chrome_trace_is_valid_json_with_all_events() {
        let tr = TraceRecorder::new();
        tr.record(EventKind::Dispatch, "fc \"quoted\"", "fpga", 0, 1, 2);
        tr.record(EventKind::KernelExec, "conv", "cpu", 3, 4, 5);
        let doc = Json::parse(&tr.to_chrome_trace()).expect("valid json");
        let events = doc.get("traceEvents").as_arr().unwrap();
        // 2 metadata (one per track) + 2 events.
        assert_eq!(events.len(), 4);
        let x_events: Vec<_> = events
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("X"))
            .collect();
        assert_eq!(x_events.len(), 2);
        assert_eq!(x_events[0].get("name").as_str(), Some("fc \"quoted\""));
        assert_eq!(x_events[1].get("cat").as_str(), Some("kernel"));
    }

    #[test]
    fn tracks_get_distinct_pids() {
        let tr = TraceRecorder::new();
        tr.record(EventKind::Custom, "a", "t1", 0, 0, 1);
        tr.record(EventKind::Custom, "b", "t2", 0, 0, 1);
        let doc = Json::parse(&tr.to_chrome_trace()).unwrap();
        let pids: Vec<f64> = doc
            .get("traceEvents")
            .as_arr()
            .unwrap()
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("X"))
            .map(|e| e.get("pid").as_f64().unwrap())
            .collect();
        assert_ne!(pids[0], pids[1]);
    }

    #[test]
    fn record_ending_now_has_sane_bounds() {
        let tr = TraceRecorder::new();
        std::thread::sleep(std::time::Duration::from_millis(2));
        tr.record_ending_now(EventKind::Reconfig, "r", "fpga", 0, 1000);
        let doc = Json::parse(&tr.to_chrome_trace()).unwrap();
        let ev = doc
            .get("traceEvents")
            .as_arr()
            .unwrap()
            .iter()
            .find(|e| e.get("ph").as_str() == Some("X"))
            .unwrap()
            .clone();
        assert_eq!(ev.get("dur").as_usize(), Some(1000));
    }

    #[test]
    fn concurrent_recording_is_safe() {
        let tr = TraceRecorder::new();
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let tr = tr.clone();
                std::thread::spawn(move || {
                    for i in 0..100 {
                        tr.record(EventKind::Custom, format!("e{t}-{i}"), "t", t, i, 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(tr.len(), 400);
        Json::parse(&tr.to_chrome_trace()).expect("valid json");
    }

    #[test]
    fn ring_caps_memory_and_counts_drops() {
        // Regression for unbounded growth under serving load: flood well
        // past the cap and check that the ring holds exactly `cap` events,
        // every older event was counted as dropped, and the survivors are
        // the newest ones.
        let cap = 64;
        let tr = TraceRecorder::with_capacity(cap);
        for i in 0..1000u64 {
            tr.record(EventKind::Custom, format!("e{i}"), "t", 0, i, 1);
        }
        assert_eq!(tr.len(), cap);
        assert_eq!(tr.dropped(), 1000 - cap as u64);
        assert_eq!(tr.capacity(), cap);
        let doc = Json::parse(&tr.to_chrome_trace()).unwrap();
        let starts: Vec<usize> = doc
            .get("traceEvents")
            .as_arr()
            .unwrap()
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("X"))
            .map(|e| e.get("ts").as_usize().unwrap())
            .collect();
        assert_eq!(starts.len(), cap);
        assert_eq!(*starts.iter().min().unwrap(), 1000 - cap);
        assert_eq!(*starts.iter().max().unwrap(), 999);
    }

    #[test]
    fn windowed_export_keeps_only_recent_events() {
        let tr = TraceRecorder::new();
        tr.record(EventKind::Custom, "old", "t", 0, 0, 10); // ends at 10
        tr.record(EventKind::Custom, "recent", "t", 0, 500, 10); // ends at 510
        let doc = Json::parse(&tr.to_chrome_trace_since(100)).unwrap();
        let names: Vec<&str> = doc
            .get("traceEvents")
            .as_arr()
            .unwrap()
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("X"))
            .filter_map(|e| e.get("name").as_str())
            .collect();
        assert_eq!(names, vec!["recent"]);
        // An event still running at the cutoff is kept.
        let doc = Json::parse(&tr.to_chrome_trace_since(505)).unwrap();
        let n = doc
            .get("traceEvents")
            .as_arr()
            .unwrap()
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("X"))
            .count();
        assert_eq!(n, 1);
    }

    #[test]
    fn names_with_escapes_and_control_chars_stay_parseable() {
        // The util::json parser is the oracle: every hostile name must
        // round-trip through the Chrome-trace export.
        let hostile = [
            "back\\slash",
            "quote\"inside",
            "newline\nhere",
            "tab\there",
            "ctrl\u{1}char",
            "mixed \"\\\n\t\u{2} soup",
        ];
        let tr = TraceRecorder::new();
        for (i, name) in hostile.iter().enumerate() {
            tr.record(EventKind::Custom, *name, "t", i as u32, i as u64, 1);
        }
        let doc = Json::parse(&tr.to_chrome_trace()).expect("hostile names must stay valid JSON");
        let names: Vec<String> = doc
            .get("traceEvents")
            .as_arr()
            .unwrap()
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("X"))
            .filter_map(|e| e.get("name").as_str().map(|s| s.to_string()))
            .collect();
        assert_eq!(names.len(), hostile.len());
        for (got, want) in names.iter().zip(hostile.iter()) {
            assert_eq!(got, want, "name must round-trip exactly");
        }
    }
}
