//! The trace recorder: thread-safe event sink + Chrome-trace JSON export.
//!
//! Events use the Trace Event Format's complete events (`"ph":"X"`): a
//! name, a category, a start timestamp (µs) and a duration. Tracks map to
//! the simulated devices ("pid" = device, "tid" = region/queue), so a
//! reconfiguration appears as a block on its PR region's track.

use std::fmt::Write as _;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Event categories.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    Dispatch,
    Reconfig,
    KernelExec,
    Barrier,
    Custom,
}

impl EventKind {
    fn category(self) -> &'static str {
        match self {
            EventKind::Dispatch => "dispatch",
            EventKind::Reconfig => "reconfig",
            EventKind::KernelExec => "kernel",
            EventKind::Barrier => "barrier",
            EventKind::Custom => "custom",
        }
    }
}

#[derive(Debug, Clone)]
struct Event {
    name: String,
    kind: EventKind,
    track: String,
    lane: u32,
    start_us: u64,
    dur_us: u64,
}

/// Cloneable, thread-safe recorder.
#[derive(Debug, Clone)]
pub struct TraceRecorder {
    inner: Arc<Inner>,
}

#[derive(Debug)]
struct Inner {
    epoch: Instant,
    events: Mutex<Vec<Event>>,
}

impl Default for TraceRecorder {
    fn default() -> Self {
        TraceRecorder::new()
    }
}

impl TraceRecorder {
    pub fn new() -> TraceRecorder {
        TraceRecorder {
            inner: Arc::new(Inner { epoch: Instant::now(), events: Mutex::new(Vec::new()) }),
        }
    }

    /// Current timestamp in µs since the recorder's epoch.
    pub fn now_us(&self) -> u64 {
        self.inner.epoch.elapsed().as_micros() as u64
    }

    /// Record a complete event with explicit timing.
    pub fn record(
        &self,
        kind: EventKind,
        name: impl Into<String>,
        track: impl Into<String>,
        lane: u32,
        start_us: u64,
        dur_us: u64,
    ) {
        self.inner.events.lock().unwrap().push(Event {
            name: name.into(),
            kind,
            track: track.into(),
            lane,
            start_us,
            dur_us,
        });
    }

    /// Record an event that started `dur_us` ago and ends now.
    pub fn record_ending_now(
        &self,
        kind: EventKind,
        name: impl Into<String>,
        track: impl Into<String>,
        lane: u32,
        dur_us: u64,
    ) {
        let end = self.now_us();
        self.record(kind, name, track, lane, end.saturating_sub(dur_us), dur_us);
    }

    pub fn len(&self) -> usize {
        self.inner.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Export as Chrome Trace Event Format JSON.
    pub fn to_chrome_trace(&self) -> String {
        let events = self.inner.events.lock().unwrap();
        // Stable pid mapping per track name.
        let mut tracks: Vec<&str> = events.iter().map(|e| e.track.as_str()).collect();
        tracks.sort();
        tracks.dedup();
        let pid_of = |t: &str| tracks.iter().position(|x| *x == t).unwrap() + 1;

        let mut out = String::from("{\"traceEvents\":[");
        for (i, t) in tracks.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "{{\"ph\":\"M\",\"pid\":{},\"name\":\"process_name\",\"args\":{{\"name\":{}}}}}",
                i + 1,
                crate::util::json::Json::Str(t.to_string())
            );
        }
        for e in events.iter() {
            let _ = write!(
                out,
                ",{{\"ph\":\"X\",\"pid\":{},\"tid\":{},\"ts\":{},\"dur\":{},\"cat\":\"{}\",\"name\":{}}}",
                pid_of(&e.track),
                e.lane,
                e.start_us,
                e.dur_us,
                e.kind.category(),
                crate::util::json::Json::Str(e.name.clone())
            );
        }
        out.push_str("]}");
        out
    }

    /// Write the trace to a file.
    pub fn write_to(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_chrome_trace())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn records_and_counts() {
        let tr = TraceRecorder::new();
        assert!(tr.is_empty());
        tr.record(EventKind::Dispatch, "fc", "fpga", 0, 10, 5);
        tr.record(EventKind::Reconfig, "role3", "fpga", 1, 15, 7425);
        assert_eq!(tr.len(), 2);
    }

    #[test]
    fn chrome_trace_is_valid_json_with_all_events() {
        let tr = TraceRecorder::new();
        tr.record(EventKind::Dispatch, "fc \"quoted\"", "fpga", 0, 1, 2);
        tr.record(EventKind::KernelExec, "conv", "cpu", 3, 4, 5);
        let doc = Json::parse(&tr.to_chrome_trace()).expect("valid json");
        let events = doc.get("traceEvents").as_arr().unwrap();
        // 2 metadata (one per track) + 2 events.
        assert_eq!(events.len(), 4);
        let x_events: Vec<_> = events
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("X"))
            .collect();
        assert_eq!(x_events.len(), 2);
        assert_eq!(x_events[0].get("name").as_str(), Some("fc \"quoted\""));
        assert_eq!(x_events[1].get("cat").as_str(), Some("kernel"));
    }

    #[test]
    fn tracks_get_distinct_pids() {
        let tr = TraceRecorder::new();
        tr.record(EventKind::Custom, "a", "t1", 0, 0, 1);
        tr.record(EventKind::Custom, "b", "t2", 0, 0, 1);
        let doc = Json::parse(&tr.to_chrome_trace()).unwrap();
        let pids: Vec<f64> = doc
            .get("traceEvents")
            .as_arr()
            .unwrap()
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("X"))
            .map(|e| e.get("pid").as_f64().unwrap())
            .collect();
        assert_ne!(pids[0], pids[1]);
    }

    #[test]
    fn record_ending_now_has_sane_bounds() {
        let tr = TraceRecorder::new();
        std::thread::sleep(std::time::Duration::from_millis(2));
        tr.record_ending_now(EventKind::Reconfig, "r", "fpga", 0, 1000);
        let doc = Json::parse(&tr.to_chrome_trace()).unwrap();
        let ev = doc
            .get("traceEvents")
            .as_arr()
            .unwrap()
            .iter()
            .find(|e| e.get("ph").as_str() == Some("X"))
            .unwrap()
            .clone();
        assert_eq!(ev.get("dur").as_usize(), Some(1000));
    }

    #[test]
    fn concurrent_recording_is_safe() {
        let tr = TraceRecorder::new();
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let tr = tr.clone();
                std::thread::spawn(move || {
                    for i in 0..100 {
                        tr.record(EventKind::Custom, format!("e{t}-{i}"), "t", t, i, 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(tr.len(), 400);
        Json::parse(&tr.to_chrome_trace()).expect("valid json");
    }
}
