//! Request-scoped span context: the thread that connects one inference —
//! from HTTP accept to kernel retire — across the admission gate, the
//! batcher, the router, and the completer.
//!
//! A [`SpanCtx`] is a cheap clone-and-share handle (an `Arc` around the
//! request id, a [`TraceRecorder`] and the accumulated stage timings).
//! Pipeline stages call [`SpanCtx::record_stage`] as they finish their
//! part of the work; each call both appends to the span's private stage
//! list (for the `X-Timing` header and the slow-request log) and emits a
//! Chrome-trace event on the request's own track (`req:<id>`), so a
//! Perfetto load shows the request as a lane aligned with the device
//! timeline the same recorder carries.
//!
//! `SpanCtx::disabled()` is a no-op handle: every method is a cheap
//! branch on `None`, so untraced paths (internal warmup, benches with
//! tracing off) pay a single pointer-sized `Option` per request.

use crate::trace::recorder::{EventKind, TraceRecorder};
use std::sync::{Arc, Mutex};

/// The per-request pipeline stages the serving stack attributes latency
/// to. Names are the Prometheus/`X-Timing` identifiers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Admission-control time: rate-limiter + pending-gate + request
    /// parse, before the request enters a batching lane.
    AdmissionWait,
    /// Arrival in a lane until the batch containing this request was
    /// taken for dispatch (queue + deadline wait; late joins shorten it).
    BatchWait,
    /// Sealing the taken batch: padding, tensor construction.
    BatchAssembly,
    /// Submitting the sealed batch to the session (placement + shard
    /// routing + async dispatch).
    Route,
    /// ICAP reconfiguration time exposed on this request's critical path
    /// (a subset of [`Stage::KernelExec`]'s window, 0 on a clean hit).
    ReconfigStall,
    /// Dispatch to completion: kernel execution plus completion wait.
    KernelExec,
    /// Encoding the reply body (JSON / base64 / binary tensor).
    ReplySerialize,
}

impl Stage {
    pub const ALL: [Stage; 7] = [
        Stage::AdmissionWait,
        Stage::BatchWait,
        Stage::BatchAssembly,
        Stage::Route,
        Stage::ReconfigStall,
        Stage::KernelExec,
        Stage::ReplySerialize,
    ];

    /// Stable snake_case identifier (Prometheus metric suffix, `X-Timing`
    /// key, trace event name).
    pub fn name(self) -> &'static str {
        match self {
            Stage::AdmissionWait => "admission_wait",
            Stage::BatchWait => "batch_wait",
            Stage::BatchAssembly => "batch_assembly",
            Stage::Route => "route",
            Stage::ReconfigStall => "reconfig_stall",
            Stage::KernelExec => "kernel_exec",
            Stage::ReplySerialize => "reply_serialize",
        }
    }

    /// Whether the stage is a disjoint slice of the request's wall time.
    /// `ReconfigStall` overlaps `KernelExec` (it attributes a subset of
    /// that window), so end-to-end reconciliation sums only the disjoint
    /// stages.
    pub fn disjoint(self) -> bool {
        !matches!(self, Stage::ReconfigStall)
    }

    fn kind(self) -> EventKind {
        match self {
            Stage::KernelExec => EventKind::KernelExec,
            Stage::ReconfigStall => EventKind::Reconfig,
            _ => EventKind::Custom,
        }
    }
}

#[derive(Debug)]
struct SpanInner {
    id: String,
    track: String,
    recorder: TraceRecorder,
    stages: Mutex<Vec<(Stage, u64)>>,
}

/// Shared per-request span handle; see the module docs. `Default` is the
/// disabled no-op handle.
#[derive(Debug, Clone, Default)]
pub struct SpanCtx {
    inner: Option<Arc<SpanInner>>,
}

impl SpanCtx {
    /// A no-op handle: all recording methods return immediately.
    pub fn disabled() -> SpanCtx {
        SpanCtx { inner: None }
    }

    /// A live span for request `id`, emitting onto `recorder`.
    pub fn new(id: impl Into<String>, recorder: TraceRecorder) -> SpanCtx {
        let id = id.into();
        let track = format!("req:{id}");
        SpanCtx {
            inner: Some(Arc::new(SpanInner {
                id,
                track,
                recorder,
                stages: Mutex::new(Vec::new()),
            })),
        }
    }

    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    pub fn id(&self) -> Option<&str> {
        self.inner.as_deref().map(|i| i.id.as_str())
    }

    /// The request's trace track name (`req:<id>`).
    pub fn track(&self) -> Option<&str> {
        self.inner.as_deref().map(|i| i.track.as_str())
    }

    /// Recorder-epoch timestamp, or 0 when disabled.
    pub fn now_us(&self) -> u64 {
        self.inner.as_deref().map_or(0, |i| i.recorder.now_us())
    }

    /// Record a stage that ends now and lasted `dur_us`: appends to the
    /// span's breakdown and emits a trace event on the request track.
    pub fn record_stage(&self, stage: Stage, dur_us: u64) {
        if let Some(inner) = &self.inner {
            inner.stages.lock().unwrap().push((stage, dur_us));
            inner
                .recorder
                .record_ending_now(stage.kind(), stage.name(), inner.track.clone(), 0, dur_us);
        }
    }

    /// Record a stage with an explicit start (recorder-epoch µs) — for
    /// stages whose window was captured earlier than it is reported.
    pub fn record_stage_at(&self, stage: Stage, start_us: u64, dur_us: u64) {
        if let Some(inner) = &self.inner {
            inner.stages.lock().unwrap().push((stage, dur_us));
            inner
                .recorder
                .record(stage.kind(), stage.name(), inner.track.clone(), 0, start_us, dur_us);
        }
    }

    /// Drop an instantaneous annotation (e.g. the routing decision) onto
    /// the request track without contributing to the stage breakdown.
    pub fn annotate(&self, name: impl Into<String>) {
        if let Some(inner) = &self.inner {
            let now = inner.recorder.now_us();
            inner
                .recorder
                .record(EventKind::Custom, name, inner.track.clone(), 0, now, 0);
        }
    }

    /// Snapshot of the stage breakdown recorded so far, in record order.
    pub fn stages(&self) -> Vec<(Stage, u64)> {
        self.inner
            .as_deref()
            .map_or_else(Vec::new, |i| i.stages.lock().unwrap().clone())
    }

    /// Sum of all disjoint stage durations (see [`Stage::disjoint`]).
    pub fn stage_total_us(&self) -> u64 {
        self.stages()
            .iter()
            .filter(|(s, _)| s.disjoint())
            .map(|(_, d)| d)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn disabled_span_is_inert() {
        let span = SpanCtx::disabled();
        assert!(!span.enabled());
        assert_eq!(span.id(), None);
        span.record_stage(Stage::Route, 10);
        span.annotate("route -> agent 0");
        assert!(span.stages().is_empty());
        assert_eq!(span.stage_total_us(), 0);
    }

    #[test]
    fn stages_accumulate_and_emit_on_the_request_track() {
        let tr = TraceRecorder::new();
        let span = SpanCtx::new("req-1", tr.clone());
        span.record_stage(Stage::AdmissionWait, 5);
        span.record_stage(Stage::BatchWait, 100);
        span.record_stage(Stage::ReconfigStall, 40);
        span.record_stage(Stage::KernelExec, 60);
        assert_eq!(span.stages().len(), 4);
        // reconfig_stall overlaps kernel_exec, so it is excluded from the
        // disjoint total.
        assert_eq!(span.stage_total_us(), 165);
        let doc = Json::parse(&tr.to_chrome_trace()).unwrap();
        let events = doc.get("traceEvents").as_arr().unwrap().clone();
        let on_track = events
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("M"))
            .filter_map(|e| e.get("args").get("name").as_str())
            .any(|n| n == "req:req-1");
        assert!(on_track, "request track metadata must be present");
        let names: Vec<&str> = events
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("X"))
            .filter_map(|e| e.get("name").as_str())
            .collect();
        assert!(names.contains(&"admission_wait"));
        assert!(names.contains(&"kernel_exec"));
    }

    #[test]
    fn clones_share_the_breakdown() {
        // The HTTP handler's clone must see stages the pipeline threads
        // recorded on theirs.
        let span = SpanCtx::new("req-2", TraceRecorder::new());
        let pipeline_side = span.clone();
        std::thread::spawn(move || {
            pipeline_side.record_stage(Stage::KernelExec, 77);
        })
        .join()
        .unwrap();
        assert_eq!(span.stages(), vec![(Stage::KernelExec, 77)]);
    }

    #[test]
    fn stage_names_are_stable_identifiers() {
        let names: Vec<&str> = Stage::ALL.iter().map(|s| s.name()).collect();
        assert_eq!(
            names,
            vec![
                "admission_wait",
                "batch_wait",
                "batch_assembly",
                "route",
                "reconfig_stall",
                "kernel_exec",
                "reply_serialize",
            ]
        );
    }
}
