//! `tf-fpga` CLI: reproduce the paper's tables, run the ablations, drive
//! the end-to-end workloads.
//!
//! ```text
//! tf-fpga info                      # stack / device / artifact summary
//! tf-fpga table1                    # Table I  (PL utilization)
//! tf-fpga table2 [--n 1000]         # Table II (overheads, µs)
//! tf-fpga table3 [--n 1000]         # Table III (OP/cycle increase)
//! tf-fpga tables                    # all three
//! tf-fpga ablate-eviction [...]     # LRU/FIFO/Random/MRU/Belady sweep
//! tf-fpga ablate-regions [...]      # PR-region-count sweep
//! tf-fpga crossover                 # reconfiguration amortization point
//! tf-fpga run-mnist [--batches 32]  # end-to-end CNN inference
//! tf-fpga export-demo [dir]         # write demo model bundles
//! tf-fpga import-onnx m.onnx out/   # import an ONNX model as a bundle
//! tf-fpga serve --model <dir>       # serve an exported bundle (async)
//! tf-fpga serve --fpga-pool 2       # shard serving across an FPGA pool
//! tf-fpga serve --http 0.0.0.0:8080 # HTTP frontend with admission control
//! ```

use anyhow::{bail, Result};
use std::collections::HashMap;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, flags, positional) = parse(&args)?;
    // Most commands take no positional arguments; a stray token is almost
    // certainly a typo'd flag (e.g. `serve async`).
    let allowed_positionals = match cmd.as_str() {
        "export-demo" => 1,            // output directory
        "import-onnx" => 2,            // model.onnx + bundle directory
        _ => 0,
    };
    if let Some(stray) = positional.get(allowed_positionals) {
        bail!("unexpected argument '{stray}' (try `tf-fpga help`)");
    }
    match cmd.as_str() {
        "info" => info(),
        "table1" => {
            println!("{}", tf_fpga::bench::tables::table1());
            Ok(())
        }
        "table2" => {
            let n = flag_usize(&flags, "n", 1000);
            let use_pjrt = !flags.contains_key("no-pjrt");
            let (t, _) = tf_fpga::bench::tables::table2(n, use_pjrt);
            println!("{t}");
            Ok(())
        }
        "table3" => {
            let n = flag_usize(&flags, "n", 1000);
            let (t, _) = tf_fpga::bench::tables::table3(n);
            println!("{t}");
            Ok(())
        }
        "tables" => {
            println!("{}", tf_fpga::bench::tables::table1());
            let n = flag_usize(&flags, "n", 1000);
            let (t2, _) = tf_fpga::bench::tables::table2(n, !flags.contains_key("no-pjrt"));
            println!("{t2}");
            let (t3, _) = tf_fpga::bench::tables::table3(n);
            println!("{t3}");
            Ok(())
        }
        "ablate-eviction" => ablate_eviction(
            flag_usize(&flags, "regions", 2),
            flag_usize(&flags, "roles", 4),
            flag_usize(&flags, "n", 2000),
        ),
        "ablate-regions" => ablate_regions(flag_usize(&flags, "n", 2000)),
        "crossover" => crossover(),
        "run-mnist" => run_mnist(
            flag_usize(&flags, "batches", 8),
            flag_usize(&flags, "batch-size", 32),
            session_opts_from_flags(&flags)?,
        ),
        "serve" if flags.contains_key("http") => serve_http(
            match flags.get("http").map(String::as_str) {
                Some("true") | None => "127.0.0.1:8080".to_string(),
                Some(addr) => addr.to_string(),
            },
            flag_usize(&flags, "max-pending", 64),
            flag_usize(&flags, "tenant-rps", 0),
            flag_usize(&flags, "http-workers", 8),
            flag_usize(&flags, "serve-secs", 0),
            flag_usize(&flags, "max-batch", 16),
            flag_usize(&flags, "max-delay-ms", 3),
            flag_usize(&flags, "pipeline-depth", 4),
            flag_usize(&flags, "workers", 2),
            flag_usize(&flags, "fpga-pool", 1),
            shard_strategy_from_flags(&flags)?,
            flag_usize(&flags, "prefetch-depth", 0),
            flags.get("model").cloned(),
            flag_usize(&flags, "slow-request-ms", 1000),
        ),
        "serve"
            if flags.contains_key("async")
                || flags.contains_key("model")
                || flags.contains_key("fpga-pool") =>
        {
            let strategy = shard_strategy_from_flags(&flags)?;
            serve_async(
                flag_usize(&flags, "requests", 512),
                flag_usize(&flags, "clients", 4),
                flag_usize(&flags, "max-batch", 16),
                flag_usize(&flags, "max-delay-ms", 3),
                flag_usize(&flags, "pipeline-depth", 4),
                flag_usize(&flags, "workers", 2),
                flag_usize(&flags, "fpga-pool", 1),
                strategy,
                flag_usize(&flags, "prefetch-depth", 0),
                flags.get("model").cloned(),
            )
        }
        "serve" => serve(
            flag_usize(&flags, "requests", 512),
            flag_usize(&flags, "clients", 4),
            flag_usize(&flags, "max-batch", 16),
            flag_usize(&flags, "max-delay-ms", 3),
            flags.get("trace-out").cloned(),
        ),
        "export-demo" => export_demo(
            positional
                .first()
                .map(String::as_str)
                .or_else(|| flags.get("out").map(String::as_str))
                .unwrap_or("demo-bundles"),
        ),
        "import-onnx" => {
            let (Some(model), Some(dir)) = (positional.first(), positional.get(1)) else {
                bail!("usage: tf-fpga import-onnx <model.onnx> <bundle-dir>");
            };
            import_onnx(model, dir)
        }
        "ablate-hls" => ablate_hls(),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `tf-fpga help`)"),
    }
}

const HELP: &str = "tf-fpga — Transparent FPGA Acceleration with TensorFlow (reproduction)

commands:
  info                     stack / device / artifact summary
  table1                   Table I: PL utilization
  table2 [--n N]           Table II: overheads [µs] (--no-pjrt to skip PJRT setup)
  table3 [--n N]           Table III: OP/cycle increase over the A53
  tables [--n N]           all three tables
  ablate-eviction [--regions R --roles K --n N]
                           eviction-policy ablation (LRU/FIFO/Random/MRU/Belady)
  ablate-regions [--n N]   PR-region-count sweep
  crossover                dispatches needed for the FPGA to amortize reconfiguration
  run-mnist [--batches B --batch-size S]
                           end-to-end CNN inference through the full stack
  serve [--requests N --clients C --max-batch B --max-delay-ms D --trace-out F]
                           dynamic-batching inference service + latency report
  serve --async [--pipeline-depth P --workers W ...]
                           async batched pipeline (overlapped dispatch/completion)
  serve --model DIR [...]  serve a model bundle directory (async pipeline);
                           see `export-demo` and `python -m compile.export`
  serve --fpga-pool N [--shard-strategy S ...]
                           shard the async pipeline across N FPGA agents
                           (S: round-robin | least-loaded | kernel-affinity)
  serve --prefetch-depth N [...]
                           predictive reconfiguration: prefetch the next N
                           upcoming roles onto idle PR regions so ICAP
                           transfers overlap compute (0 = off, the default)
  serve --http [ADDR] [--max-pending N --tenant-rps R --http-workers W
                --serve-secs T --slow-request-ms MS --model DIR ...]
                           HTTP/1.1 frontend (default 127.0.0.1:8080) over the
                           async pipeline: POST /v1/models/<name>:predict,
                           GET /v1/models | /healthz | /metrics (Prometheus).
                           Sheds load with 429 + Retry-After past N pending
                           requests; rate-limits per X-Tenant header at R req/s
                           (0 = unlimited); honors X-Deadline-Ms; drains
                           gracefully after T seconds (0 = run until killed).
                           Every request is traced accept-to-retire: X-Request-Id
                           minted/echoed, per-stage histograms on /metrics,
                           GET /v1/debug/trace?last_ms=N dumps the flight
                           recorder as Perfetto-ready Chrome-trace JSON, and
                           requests over MS milliseconds (default 1000) log
                           their stage breakdown
  export-demo [DIR]        write the built-in demo model bundles to DIR
                           (mnist, mnist_layers, tiny_fc; default ./demo-bundles)
  import-onnx FILE DIR     import an ONNX model (Conv/BN/Relu/MaxPool/Add/
                           Concat/GlobalAveragePool/Gemm/Softmax subset) as a
                           serveable bundle; BatchNormalization is folded into
                           the preceding Conv/Gemm weights at import time.
                           Serve it with `serve --model DIR [--http ...]`
  ablate-hls               pre-synthesized vs online-synthesis (OpenCL) flow costs
";

fn parse(args: &[String]) -> Result<(String, HashMap<String, String>, Vec<String>)> {
    if args.is_empty() {
        return Ok(("help".into(), HashMap::new(), Vec::new()));
    }
    let cmd = args[0].clone();
    let mut flags = HashMap::new();
    let mut positional = Vec::new();
    let mut i = 1;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            let value = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            flags.insert(name.to_string(), value);
        } else {
            positional.push(a.clone());
        }
        i += 1;
    }
    Ok((cmd, flags, positional))
}

fn flag_usize(flags: &HashMap<String, String>, name: &str, default: usize) -> usize {
    flags
        .get(name)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// `--prefetch-depth N` → predictive-reconfiguration policy (0 keeps the
/// paper's reactive behaviour — prefetch off).
fn prefetch_from_depth(depth: usize) -> tf_fpga::reconfig::PrefetchPolicy {
    if depth == 0 {
        tf_fpga::reconfig::PrefetchPolicy::default()
    } else {
        tf_fpga::reconfig::PrefetchPolicy::with_depth(depth)
    }
}

fn shard_strategy_from_flags(
    flags: &HashMap<String, String>,
) -> Result<tf_fpga::sharding::ShardStrategy> {
    match flags.get("shard-strategy") {
        Some(s) => tf_fpga::sharding::ShardStrategy::parse(s).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown --shard-strategy '{s}' \
                 (round-robin | least-loaded | kernel-affinity)"
            )
        }),
        None => Ok(tf_fpga::sharding::ShardStrategy::KernelAffinity),
    }
}

/// `--config <file>` loads `[session]` options (see util::config); other
/// flags still win where both are given.
fn session_opts_from_flags(
    flags: &HashMap<String, String>,
) -> Result<tf_fpga::tf::session::SessionOptions> {
    let mut opts = match flags.get("config") {
        Some(path) => tf_fpga::util::config::Config::load(path)
            .and_then(|c| c.session_options())
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        None => tf_fpga::tf::session::SessionOptions::default(),
    };
    if let Some(r) = flags.get("regions").and_then(|v| v.parse().ok()) {
        opts.num_regions = r;
    }
    Ok(opts)
}

fn info() -> Result<()> {
    use tf_fpga::fpga::resources::ZU3EG;
    println!("tf-fpga: Transparent FPGA Acceleration with TensorFlow (reproduction)");
    println!();
    println!("device model : Ultra96 / Zynq UltraScale+ ZU3EG (simulated)");
    println!("  PL         : {ZU3EG}");
    println!("  shell      : {}", tf_fpga::fpga::roles::shell_resources());
    println!(
        "  reconfig   : {} µs per role ({} B @ PCAP)",
        tf_fpga::fpga::icap::Icap::default()
            .reconfig_time_us(tf_fpga::fpga::roles::ROLE_BITSTREAM_BYTES),
        tf_fpga::fpga::roles::ROLE_BITSTREAM_BYTES
    );
    println!("cpu baseline : ARM Cortex-A53 model @ 1200 MHz");
    match tf_fpga::runtime::artifact::ArtifactStore::open_default() {
        Ok(store) => {
            println!("artifacts    : {} ({} modules)", store.dir.display(), store.modules.len());
            for (name, m) in &store.modules {
                println!(
                    "  {name:18} {:>10}  in={:?}",
                    m.hlo_path.file_name().unwrap().to_string_lossy(),
                    m.inputs.iter().map(|i| format!("{:?}:{}", i.shape, i.dtype)).collect::<Vec<_>>()
                );
            }
        }
        Err(e) => println!("artifacts    : not available ({e})"),
    }
    Ok(())
}

fn ablate_eviction(regions: usize, roles: usize, n: usize) -> Result<()> {
    use tf_fpga::fpga::bitstream::Bitstream;
    use tf_fpga::fpga::icap::Icap;
    use tf_fpga::fpga::resources::ResourceVector;
    use tf_fpga::fpga::roles::role3_spec;
    use tf_fpga::metrics::report::Table;
    use tf_fpga::reconfig::manager::ReconfigManager;
    use tf_fpga::reconfig::policy::{BeladyOracle, EvictionPolicy, PolicyKind};
    use tf_fpga::util::prng::Rng;

    let mk_roles = || -> Vec<Bitstream> {
        (0..roles)
            .map(|i| {
                Bitstream::new(
                    format!("role{i}"),
                    tf_fpga::fpga::roles::ROLE_BITSTREAM_BYTES,
                    ResourceVector::new(100, 100, 10, 10),
                    role3_spec(),
                )
            })
            .collect()
    };

    // Workloads: cyclic (LRU-pathological), zipf-skewed, uniform random.
    let traces: Vec<(&str, Vec<usize>)> = {
        let mut rng = Rng::new(7);
        let cyclic: Vec<usize> = (0..n).map(|i| i % roles).collect();
        let zipf: Vec<usize> = (0..n).map(|_| rng.zipf(roles, 1.2)).collect();
        let uniform: Vec<usize> = (0..n).map(|_| rng.below(roles as u64) as usize).collect();
        vec![("cyclic", cyclic), ("zipf(1.2)", zipf), ("uniform", uniform)]
    };

    let mut table = Table::new(
        format!("Eviction-policy ablation: {roles} roles, {regions} regions, n={n}"),
        &["Trace", "Policy", "Hit rate", "Reconfig time [ms]"],
    );
    for (trace_name, trace) in &traces {
        let mut run = |name: &str, mut policy: Box<dyn EvictionPolicy>| {
            let bitstreams = mk_roles();
            // Belady needs the trace up front.
            if name == "belady" {
                policy = Box::new(BeladyOracle::new(
                    trace.iter().map(|&i| bitstreams[i].id).collect(),
                ));
            }
            let mut mgr = ReconfigManager::with_uniform_regions(
                regions,
                ResourceVector::new(1000, 1000, 100, 100),
                policy,
                Icap::default(),
            );
            for &i in trace {
                mgr.ensure_loaded(&bitstreams[i]).unwrap();
            }
            let s = mgr.stats();
            table.row(&[
                trace_name.to_string(),
                name.to_string(),
                format!("{:.1}%", 100.0 * s.hit_rate()),
                format!("{:.1}", s.reconfig_us_total as f64 / 1000.0),
            ]);
        };
        for kind in PolicyKind::ALL {
            run(kind.build(1).name(), kind.build(1));
        }
        run("belady", PolicyKind::Lru.build(0) /* replaced above */);
    }
    println!("{table}");
    Ok(())
}

fn ablate_regions(n: usize) -> Result<()> {
    use tf_fpga::fpga::bitstream::Bitstream;
    use tf_fpga::fpga::icap::Icap;
    use tf_fpga::fpga::resources::ResourceVector;
    use tf_fpga::fpga::roles::role3_spec;
    use tf_fpga::metrics::report::Table;
    use tf_fpga::reconfig::manager::ReconfigManager;
    use tf_fpga::reconfig::policy::Lru;
    use tf_fpga::util::prng::Rng;

    let roles = 4;
    let mut table = Table::new(
        format!("PR-region-count sweep (LRU, {roles} roles, zipf(1.2), n={n})"),
        &["Regions", "Hit rate", "Reconfigs", "Reconfig time [ms]"],
    );
    for regions in 1..=roles {
        let bitstreams: Vec<Bitstream> = (0..roles)
            .map(|i| {
                Bitstream::new(
                    format!("role{i}"),
                    tf_fpga::fpga::roles::ROLE_BITSTREAM_BYTES,
                    ResourceVector::new(100, 100, 10, 10),
                    role3_spec(),
                )
            })
            .collect();
        let mut mgr = ReconfigManager::with_uniform_regions(
            regions,
            ResourceVector::new(1000, 1000, 100, 100),
            Box::new(Lru),
            Icap::default(),
        );
        let mut rng = Rng::new(11);
        for _ in 0..n {
            let i = rng.zipf(roles, 1.2);
            mgr.ensure_loaded(&bitstreams[i]).unwrap();
        }
        let s = mgr.stats();
        table.row(&[
            regions.to_string(),
            format!("{:.1}%", 100.0 * s.hit_rate()),
            s.misses.to_string(),
            format!("{:.1}", s.reconfig_us_total as f64 / 1000.0),
        ]);
    }
    println!("{table}");
    Ok(())
}

fn crossover() -> Result<()> {
    use tf_fpga::cpu::a53::A53Model;
    use tf_fpga::fpga::icap::Icap;
    use tf_fpga::fpga::roles;
    use tf_fpga::metrics::report::Table;

    let icap = Icap::default();
    let reconfig_us = icap.reconfig_time_us(roles::ROLE_BITSTREAM_BYTES) as f64;
    let cpu = A53Model::default();
    let mut table = Table::new(
        "Reconfiguration amortization: dispatches for FPGA (reconfig + exec) to beat the A53",
        &["Role", "FPGA exec [µs]", "A53 exec [µs]", "OP/cycle win", "Latency break-even"],
    );
    for spec in [
        roles::role1_spec(),
        roles::role2_spec(),
        roles::role3_spec(),
        roles::role4_spec(),
    ] {
        let fpga_us = spec.exec_ns(&spec.op) as f64 / 1000.0;
        let cpu_us = cpu.exec_ns(&spec.op) as f64 / 1000.0;
        let opc_win = spec.ops_per_cycle(&spec.op) / cpu.achieved_ops_per_cycle(&spec.op);
        let be = if cpu_us > fpga_us {
            format!("{:.0}", (reconfig_us / (cpu_us - fpga_us)).ceil())
        } else {
            "never (A53 clock 8x)".to_string()
        };
        table.row(&[
            spec.name.to_string(),
            format!("{fpga_us:.1}"),
            format!("{cpu_us:.1}"),
            format!("{opc_win:.2}x"),
            be,
        ]);
    }
    table.footnote(format!(
        "reconfig = {reconfig_us:.0} µs (modeled PCAP); break-even = reconfig / (A53 - FPGA time)"
    ));
    table.footnote(
        "the paper claims OP/cycle (energy) efficiency: the 150 MHz FC roles win per cycle \
         but not wall-clock vs the 1200 MHz A53; the conv roles win both",
    );
    println!("{table}");
    Ok(())
}

fn serve(
    requests: usize,
    clients: usize,
    max_batch: usize,
    max_delay_ms: usize,
    trace_out: Option<String>,
) -> Result<()> {
    use std::sync::Arc;
    use tf_fpga::serve::{BatchPolicy, InferenceServer, ServerConfig};
    use tf_fpga::tf::session::SessionOptions;
    use tf_fpga::trace::recorder::TraceRecorder;
    use tf_fpga::util::prng::Rng;

    let trace = trace_out.as_ref().map(|_| TraceRecorder::new());
    let srv = InferenceServer::start(ServerConfig {
        batch: BatchPolicy {
            max_batch,
            max_delay: std::time::Duration::from_millis(max_delay_ms as u64),
        },
        session: SessionOptions { trace: trace.clone(), ..SessionOptions::default() },
        ..ServerConfig::default()
    })
    .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "serving mnist_cnn: max_batch={max_batch} max_delay={max_delay_ms}ms, {clients} clients, {requests} requests"
    );

    let srv = Arc::new(srv);
    let per_client = requests / clients.max(1);
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let srv = Arc::clone(&srv);
            std::thread::spawn(move || {
                let mut rng = Rng::new(c as u64 + 1);
                for _ in 0..per_client {
                    let mut img = vec![0f32; 784];
                    rng.fill_f32_normal(&mut img, 0.0, 1.0);
                    let logits = srv.infer(img).expect("infer");
                    assert_eq!(logits.len(), 10);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();

    let rep = srv.report();
    println!("\n--- serve report ---");
    println!("requests      : {}", rep.requests);
    println!("batches       : {} (mean fill {:.1}/{max_batch})", rep.batches, rep.mean_batch_fill);
    println!(
        "latency       : mean {:.2} ms  p50 {:.2} ms  p99 {:.2} ms",
        rep.latency_us_mean / 1e3,
        rep.latency_us_p50 as f64 / 1e3,
        rep.latency_us_p99 as f64 / 1e3
    );
    println!("throughput    : {:.0} req/s", rep.requests as f64 / wall);
    println!(
        "fpga          : hit rate {:.1}%, {} reconfigs",
        100.0 * rep.reconfig.hit_rate(),
        rep.reconfig.misses
    );
    if let (Some(tr), Some(path)) = (&trace, &trace_out) {
        tr.write_to(std::path::Path::new(path))?;
        println!("trace         : wrote {} events to {path}", tr.len());
    }
    drop(srv); // Drop stops the batcher and shuts the session down.
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn serve_async(
    requests: usize,
    clients: usize,
    max_batch: usize,
    max_delay_ms: usize,
    pipeline_depth: usize,
    workers: usize,
    fpga_pool: usize,
    shard_strategy: tf_fpga::sharding::ShardStrategy,
    prefetch_depth: usize,
    model_dir: Option<String>,
) -> Result<()> {
    use std::sync::Arc;
    use tf_fpga::serve::{AsyncInferenceServer, AsyncServerConfig, BatchPolicy, ModelSpec};
    use tf_fpga::tf::session::SessionOptions;
    use tf_fpga::util::prng::Rng;

    let policy = BatchPolicy {
        max_batch,
        max_delay: std::time::Duration::from_millis(max_delay_ms as u64),
    };
    // --model <dir>: serve a loaded bundle; otherwise the built-in demo.
    let spec = match &model_dir {
        Some(dir) => ModelSpec::from_dir(dir, policy).map_err(|e| anyhow::anyhow!("{e}"))?,
        None => ModelSpec::new("mnist", policy),
    };
    let model_name = spec.name.clone();
    let srv = AsyncInferenceServer::start(AsyncServerConfig {
        models: vec![spec],
        session: SessionOptions {
            dispatch_workers: workers,
            fpga_pool,
            shard_strategy,
            prefetch: prefetch_from_depth(prefetch_depth),
            ..SessionOptions::default()
        },
        pipeline_depth,
    })
    .map_err(|e| anyhow::anyhow!("{e}"))?;
    let meta = srv.model_meta(&model_name).expect("hosted model has meta").clone();
    println!(
        "async serving '{model_name}' ({:?} -> {:?} per request): max_batch={max_batch} \
         max_delay={max_delay_ms}ms depth={pipeline_depth} workers={workers}, \
         fpga pool {fpga_pool} ({}), {clients} clients, {requests} requests",
        meta.sample_in_shape,
        meta.sample_out_shape,
        shard_strategy.name()
    );

    let srv = Arc::new(srv);
    let per_client = requests / clients.max(1);
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let srv = Arc::clone(&srv);
            let model_name = model_name.clone();
            let meta = meta.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(c as u64 + 1);
                for _ in 0..per_client {
                    let mut sample = vec![0f32; meta.in_elems];
                    rng.fill_f32_normal(&mut sample, 0.0, 1.0);
                    let row = srv.infer(&model_name, sample).expect("infer");
                    assert_eq!(row.len(), meta.out_elems);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();

    let rep = srv.report();
    println!("\n--- async serve report ---");
    println!("requests      : {} ({} completed, {} failed)", rep.requests, rep.completed, rep.failed);
    println!(
        "batches       : {} (mean fill {:.1}/{max_batch}, max in-flight {})",
        rep.batches, rep.mean_batch_fill, rep.max_inflight
    );
    println!(
        "latency       : mean {:.2} ms  p50 {:.2} ms  p99 {:.2} ms",
        rep.latency_us_mean / 1e3,
        rep.latency_us_p50 as f64 / 1e3,
        rep.latency_us_p99 as f64 / 1e3
    );
    println!("throughput    : {:.0} req/s", rep.requests as f64 / wall);
    println!(
        "fpga          : hit rate {:.1}%, {} reconfigs (pooled over {} agent(s))",
        100.0 * rep.reconfig.hit_rate(),
        rep.reconfig.misses,
        rep.pool.len()
    );
    for shard in &rep.pool {
        println!(
            "  {:<14}: {} dispatches, max in-flight {}, hit rate {:.1}%, {} reconfigs, \
             {} quarantine(s), {} retries{}",
            shard.agent,
            shard.dispatches,
            shard.max_inflight,
            100.0 * shard.reconfig.hit_rate(),
            shard.reconfig.misses,
            shard.quarantines,
            shard.retries,
            if shard.quarantined { " [QUARANTINED]" } else { "" }
        );
        if shard.reconfig.prefetches > 0 {
            println!(
                "  {:<14}  prefetch: {} issued, {} hits ({:.0}%), {} wasted, \
                 stall {} µs, overlapped {} µs",
                "",
                shard.reconfig.prefetches,
                shard.reconfig.prefetch_hits,
                100.0 * shard.reconfig.prefetch_hit_rate(),
                shard.reconfig.prefetch_wasted,
                shard.reconfig.stall_us,
                shard.reconfig.overlapped_us
            );
        }
    }
    drop(srv); // Drop drains the pipeline and shuts the session down.
    Ok(())
}

/// Serve over HTTP: the async pipeline behind the `net` frontend, with
/// admission control. Runs until Ctrl-C (or `--serve-secs N`, which
/// drains gracefully and prints the report).
#[allow(clippy::too_many_arguments)]
fn serve_http(
    addr: String,
    max_pending: usize,
    tenant_rps: usize,
    http_workers: usize,
    serve_secs: usize,
    max_batch: usize,
    max_delay_ms: usize,
    pipeline_depth: usize,
    workers: usize,
    fpga_pool: usize,
    shard_strategy: tf_fpga::sharding::ShardStrategy,
    prefetch_depth: usize,
    model_dir: Option<String>,
    slow_request_ms: usize,
) -> Result<()> {
    use tf_fpga::net::{HttpServer, HttpServerConfig};
    use tf_fpga::serve::{AsyncInferenceServer, AsyncServerConfig, BatchPolicy, ModelSpec};
    use tf_fpga::tf::session::SessionOptions;
    use tf_fpga::trace::TraceRecorder;

    let policy = BatchPolicy {
        max_batch,
        max_delay: std::time::Duration::from_millis(max_delay_ms as u64),
    };
    let spec = match &model_dir {
        Some(dir) => ModelSpec::from_dir(dir, policy).map_err(|e| anyhow::anyhow!("{e}"))?,
        None => ModelSpec::new("mnist", policy),
    };
    // One flight recorder for the whole stack: the session threads it
    // through plan replay / routing / reconfiguration, the HTTP frontend
    // (which adopts the session's recorder) adds the per-request spans,
    // and GET /v1/debug/trace reads it back out.
    let flight = TraceRecorder::new();
    let srv = AsyncInferenceServer::start(AsyncServerConfig {
        models: vec![spec],
        session: SessionOptions {
            dispatch_workers: workers,
            fpga_pool,
            shard_strategy,
            prefetch: prefetch_from_depth(prefetch_depth),
            trace: Some(flight),
            ..SessionOptions::default()
        },
        pipeline_depth,
    })
    .map_err(|e| anyhow::anyhow!("{e}"))?;
    let models = srv.models().join(", ");
    let mut server = HttpServer::start(
        srv,
        HttpServerConfig {
            addr,
            workers: http_workers,
            max_pending,
            tenant_rps: tenant_rps as u64,
            slow_request: std::time::Duration::from_millis(slow_request_ms as u64),
            ..HttpServerConfig::default()
        },
    )
    .map_err(|e| anyhow::anyhow!("{e}"))?;
    let bound = server.local_addr();
    println!(
        "http serving [{models}] on {bound}: max_pending={max_pending} tenant_rps={} \
         http_workers={http_workers}, fpga pool {fpga_pool} ({})",
        if tenant_rps == 0 { "unlimited".to_string() } else { tenant_rps.to_string() },
        shard_strategy.name()
    );
    println!("  GET  http://{bound}/v1/models");
    println!("  GET  http://{bound}/healthz   |   GET http://{bound}/metrics");
    println!("  POST http://{bound}/v1/models/<name>:predict  {{\"instances\": [[...]]}}");
    println!("  GET  http://{bound}/v1/debug/trace?last_ms=5000  (Perfetto-ready flight recorder)");
    if serve_secs > 0 {
        std::thread::sleep(std::time::Duration::from_secs(serve_secs as u64));
        println!("\n--serve-secs elapsed; draining...");
        server.shutdown();
        let rep = server.report();
        println!(
            "served {} requests ({} completed, {} failed), {} batches",
            rep.requests, rep.completed, rep.failed, rep.batches
        );
        for shard in &rep.pool {
            println!(
                "  {:<14}: {} dispatches, hit rate {:.1}%, {} quarantine(s), {} retries{}",
                shard.agent,
                shard.dispatches,
                100.0 * shard.reconfig.hit_rate(),
                shard.quarantines,
                shard.retries,
                if shard.quarantined { " [QUARANTINED]" } else { "" }
            );
            if shard.reconfig.prefetches > 0 {
                println!(
                    "  {:<14}  prefetch: {} hits / {} issued, {} wasted, \
                     stall {} µs, overlapped {} µs",
                    "",
                    shard.reconfig.prefetch_hits,
                    shard.reconfig.prefetches,
                    shard.reconfig.prefetch_wasted,
                    shard.reconfig.stall_us,
                    shard.reconfig.overlapped_us
                );
            }
        }
    } else {
        // Serve until the process is killed; Ctrl-C tears the sockets
        // down with it.
        loop {
            std::thread::park();
        }
    }
    Ok(())
}

/// Write the built-in demo bundles — the same directory format
/// `python -m compile.export` produces from the Python frontend.
fn export_demo(dir: &str) -> Result<()> {
    use tf_fpga::tf::model::ModelBundle;
    let bundles = [
        ModelBundle::mnist_demo(32),
        ModelBundle::mnist_layers_demo(),
        ModelBundle::tiny_fc_demo(8, 16, 4),
    ];
    for bundle in bundles {
        let path = std::path::Path::new(dir).join(&bundle.name);
        bundle.save(&path).map_err(|e| anyhow::anyhow!("{e}"))?;
        println!(
            "wrote {} ({} nodes, {} signature(s), artifacts {:?})",
            path.join("model.json").display(),
            bundle.graph.len(),
            bundle.signatures.len(),
            bundle.artifact_refs()
        );
    }
    println!("\nserve one with: tf-fpga serve --model {dir}/tiny_fc");
    Ok(())
}

/// Import an ONNX model and write it out as a serveable bundle directory
/// (the same `model.json` format `export-demo` produces).
fn import_onnx(model: &str, dir: &str) -> Result<()> {
    let bundle = tf_fpga::tf::onnx::import_onnx_file(model).map_err(|e| anyhow::anyhow!("{e}"))?;
    bundle.save(dir).map_err(|e| anyhow::anyhow!("{e}"))?;
    let sig = &bundle.signatures[0];
    println!(
        "imported '{}' -> {} ({} graph nodes)",
        bundle.name,
        std::path::Path::new(dir).join("model.json").display(),
        bundle.graph.len(),
    );
    println!(
        "  serve signature: {} {:?} -> {} {:?}",
        sig.inputs[0].name, sig.inputs[0].shape, sig.outputs[0].name, sig.outputs[0].shape
    );
    println!("  serve it with: tf-fpga serve --model {dir} --http 127.0.0.1:8080");
    Ok(())
}

fn ablate_hls() -> Result<()> {
    use tf_fpga::fpga::hls::HlsFlow;
    use tf_fpga::fpga::icap::Icap;
    use tf_fpga::fpga::roles;
    use tf_fpga::fpga::synthesis::estimate;
    use tf_fpga::metrics::report::Table;

    let flow = HlsFlow::default();
    let icap = Icap::default();
    let reconfig_us = icap.reconfig_time_us(roles::ROLE_BITSTREAM_BYTES);
    let mut table = Table::new(
        "Pre-synthesized bitstreams vs online OpenCL synthesis (paper §III trade-off)",
        &["Role", "Synthesis [s]", "Presynth flow [s]", "Online flow [s]", "Time x", "Energy x"],
    );
    let role_sets = [
        ("role1_fc", roles::role1_components()),
        ("role2_fc_barrier", roles::role2_components()),
        ("role3_conv5x5", roles::role3_components()),
        ("role4_conv3x3", roles::role4_components()),
    ];
    for (name, comps) in role_sets {
        let res = estimate(&comps);
        // A representative deployment: 1000 dispatches, 20 reconfigurations
        // (LRU keeps the role mostly resident).
        let cmp = flow.compare(&res, reconfig_us, 1000, 20);
        table.row(&[
            name.to_string(),
            format!("{:.0}", flow.synthesis_seconds(&res)),
            format!("{:.2}", cmp.presynth_total_s),
            format!("{:.0}", cmp.online_total_s),
            format!("{:.0}x", cmp.overhead_factor()),
            format!("{:.0}x", cmp.energy_factor()),
        ]);
    }
    table.footnote("online = on-device HLS+synthesis+P&R once, then the same reconfigurations");
    table.footnote("the paper rejects the online flow for mobile use exactly because of these factors");
    println!("{table}");
    Ok(())
}

fn run_mnist(
    batches: usize,
    batch_size: usize,
    opts: tf_fpga::tf::session::SessionOptions,
) -> Result<()> {
    use tf_fpga::tf::dtype::DType;
    use tf_fpga::tf::graph::{Graph, OpKind};
    use tf_fpga::tf::session::Session;
    use tf_fpga::tf::tensor::Tensor;
    use tf_fpga::util::prng::Rng;
    use tf_fpga::util::stats::Summary;

    let mut g = Graph::new();
    let x = g
        .placeholder("x", &[batch_size, 1, 28, 28], DType::F32)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    g.add("logits", OpKind::MnistCnn, &[x])
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let sess = Session::new(g, opts).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "session up in {:.1} ms (pjrt client {:.1} ms, compile {:.1} ms)",
        sess.setup_timing().total_us as f64 / 1000.0,
        sess.setup_timing().pjrt_client_us as f64 / 1000.0,
        sess.setup_timing().pjrt_compile_us as f64 / 1000.0
    );

    let mut rng = Rng::new(99);
    let mut lat = Vec::new();
    let mut pred_hist = [0usize; 10];
    for _ in 0..batches {
        let mut img = vec![0f32; batch_size * 784];
        rng.fill_f32_normal(&mut img, 0.0, 1.0);
        let t = Tensor::from_f32(&[batch_size, 1, 28, 28], img).unwrap();
        let t0 = std::time::Instant::now();
        let out = sess.run(&[("x", t)], &["logits"]).map_err(|e| anyhow::anyhow!("{e}"))?;
        lat.push(t0.elapsed().as_secs_f64() * 1e6);
        for row in out[0].as_f32().unwrap().chunks(10) {
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            pred_hist[argmax] += 1;
        }
    }
    let s = Summary::from_values(&lat);
    println!(
        "ran {} batches x {} images: mean {:.1} ms, p99 {:.1} ms, throughput {:.0} img/s",
        batches,
        batch_size,
        s.mean / 1000.0,
        s.p99 / 1000.0,
        batch_size as f64 / (s.mean / 1e6)
    );
    println!("prediction histogram: {pred_hist:?}");
    let rs = sess.reconfig_stats();
    println!(
        "fpga: {} dispatches, {} reconfigs ({} ms modeled), hit rate {:.1}%",
        rs.dispatches,
        rs.misses,
        rs.reconfig_us_total / 1000,
        100.0 * rs.hit_rate()
    );
    sess.shutdown();
    Ok(())
}
