//! # tf-fpga — Transparent FPGA Acceleration with TensorFlow (reproduction)
//!
//! A Rust + JAX + Pallas reproduction of Pfenning, Holzinger & Reichenbach,
//! *"Transparent FPGA Acceleration with TensorFlow"* (2021): a
//! TensorFlow-like frontend whose kernels dispatch through an
//! HSA-Foundation-style runtime onto an FPGA managed by partial
//! reconfiguration with LRU role eviction.
//!
//! Three-layer architecture (Python never on the request path):
//!
//! * **L1** (`python/compile/kernels/`) — Pallas kernels for the paper's
//!   four roles, validated against pure-jnp oracles;
//! * **L2** (`python/compile/model.py`) — jax entry points AOT-lowered to
//!   HLO text artifacts (`make artifacts`);
//! * **L3** (this crate) — the coordinator: [`tf`] frontend (graph, placer,
//!   plan compiler + replayer, session), [`hsa`] runtime (queues, signals,
//!   packet processors),
//!   [`fpga`] substrate (shell, PR regions, ICAP, datapath models, roles),
//!   [`reconfig`] (LRU & friends, including the queue-aware policy),
//!   [`cpu`] (A53 baseline), [`runtime`] (PJRT executor service for the
//!   AOT artifacts), [`ops`] (native oracle kernels), [`serve`] (the
//!   sync and async batched serving pipelines), [`bench`] (Table I–III
//!   generators).
//!
//! Quickstart (see `examples/quickstart.rs`). The first `run` for a
//! `(feeds, fetches)` shape compiles an execution plan — dead-node
//! pruning, constant folding, op fusion, slot-allocated buffers — and
//! caches it; every later `run` replays the plan without re-walking the
//! graph (see [`tf::plan`]):
//!
//! ```no_run
//! use tf_fpga::tf::{Graph, OpKind, Session, SessionOptions, Tensor, DType};
//!
//! let mut g = Graph::new();
//! let x = g.placeholder("x", &[4, 8], DType::F32).unwrap();
//! let w = g.constant("w", Tensor::zeros(&[8, 2], DType::F32)).unwrap();
//! let b = g.constant("b", Tensor::zeros(&[2], DType::F32)).unwrap();
//! g.add("y", OpKind::FullyConnected, &[x, w, b]).unwrap();
//! let sess = Session::new(g, SessionOptions::default()).unwrap();
//! let out = sess.run(&[("x", Tensor::zeros(&[4, 8], DType::F32))], &["y"]).unwrap();
//! assert_eq!(sess.plan_cache_stats().compiles, 1); // cached for replay
//! ```
//!
//! Models cross the Python → Rust boundary as **bundles** ([`tf::model`]):
//! a `model.json` directory of serialized GraphDef + named signatures,
//! written by `python -m compile.export` (or `tf-fpga export-demo`) and
//! loaded with [`tf::model::ModelBundle::load`] / invoked by endpoint
//! name through [`tf::model::Model`]:
//!
//! ```no_run
//! use tf_fpga::tf::model::{Model, ModelBundle};
//! use tf_fpga::tf::{SessionOptions, Tensor, DType};
//!
//! let model = Model::from_bundle(
//!     ModelBundle::tiny_fc_demo(8, 16, 4),
//!     SessionOptions::default(),
//! ).unwrap();
//! let out = model.invoke("serve", &[("x", Tensor::zeros(&[8, 16], DType::F32))]).unwrap();
//! assert_eq!(out[0].shape(), &[8, 4]);
//! model.shutdown();
//! ```
//!
//! Serving: [`serve::AsyncInferenceServer`] is the async batched entry
//! point — per-model micro-batch lanes (any loaded bundle, batched along
//! dim 0 of its input endpoint), `Session::run_async` dispatch,
//! and a completer pool delivering replies in completion order:
//!
//! ```no_run
//! use tf_fpga::serve::{AsyncInferenceServer, AsyncServerConfig};
//!
//! let mut srv = AsyncInferenceServer::start(AsyncServerConfig::default()).unwrap();
//! let logits = srv.infer("mnist", vec![0.0; 784]).unwrap();
//! assert_eq!(logits.len(), 10);
//! srv.stop();
//! ```
//!
//! (`cargo bench --bench serving_throughput` compares it against the
//! lock-step [`serve::InferenceServer`] baseline, and scales the async
//! pipeline across FPGA pool sizes 1/2/4.)
//!
//! Scale-out: [`sharding`] pools N independent FPGA agents behind one
//! session (`SessionOptions::fpga_pool`), with a [`sharding::Router`]
//! assigning each dispatch to an agent — round-robin, least-loaded, or
//! kernel-affinity (replica-aware, reconfiguration-avoiding) routing.
//!
//! Remote clients reach all of the above through [`net`]: a std-only
//! HTTP/1.1 frontend (`tf-fpga serve --http <addr>`) with per-tenant
//! rate limiting, bounded-queue load shedding (`429` + `Retry-After`),
//! pre-dispatch deadline cancellation and Prometheus `/metrics`.

pub mod bench;
pub mod cpu;
pub mod fpga;
pub mod hsa;
pub mod metrics;
pub mod net;
pub mod ops;
pub mod reconfig;
pub mod runtime;
pub mod serve;
pub mod sharding;
pub mod tf;
pub mod trace;
pub mod util;
