//! Eviction policies for PR regions.
//!
//! The policy sees a read-only view of every *occupied* region (metadata
//! only — resident role, load tick, last-use tick) and picks the victim.
//! LRU is the paper's scheme; the others exist for the ablation bench
//! (`cargo bench --bench ablations`). [`QueueAwareLru`] extends LRU with
//! *queued-demand hints* from the serving batcher: a role with requests
//! waiting in the micro-batch queues is spared even if it is the least
//! recently *dispatched* — under async serving, "recently used" lags
//! "about to be used" by a whole pipeline depth.

use crate::fpga::bitstream::RoleId;
use crate::util::prng::Rng;
use std::collections::BTreeMap;

/// Metadata the policy may inspect per candidate region.
#[derive(Debug, Clone, Copy)]
pub struct RegionView {
    pub region_id: usize,
    pub role: RoleId,
    pub loaded_at_tick: u64,
    pub last_used_tick: u64,
}

/// An eviction policy picks the index (into `candidates`) of the victim.
pub trait EvictionPolicy: Send {
    fn name(&self) -> &'static str;
    fn pick_victim(&mut self, candidates: &[RegionView]) -> usize;
    /// Observation hook: a role was dispatched (Belady consumes its trace).
    fn on_access(&mut self, _role: RoleId) {}
    /// Demand hook: the serving layer reports that `queued` requests are
    /// currently waiting on `role` (0 clears the hint). Policies that do
    /// not model queued demand ignore it.
    fn on_demand(&mut self, _role: RoleId, _queued: u64) {}
    /// Aging hook: a serving batch retired, so queued-demand hints are a
    /// batch staler. Demand-blind policies ignore it. Without decay a
    /// signature that spiked once would stay protected from eviction
    /// forever (the hint is only overwritten while its lane still gets
    /// requests — a lane that goes quiet never publishes the zero).
    fn decay_demand(&mut self) {}
}

/// Least-recently-used — the paper's shipped policy.
#[derive(Debug, Default)]
pub struct Lru;

impl EvictionPolicy for Lru {
    fn name(&self) -> &'static str {
        "lru"
    }
    fn pick_victim(&mut self, candidates: &[RegionView]) -> usize {
        candidates
            .iter()
            .enumerate()
            .min_by_key(|(_, c)| c.last_used_tick)
            .map(|(i, _)| i)
            .expect("pick_victim on empty candidate set")
    }
}

/// Most-recently-used (pathological counterpoint for cyclic traces).
#[derive(Debug, Default)]
pub struct Mru;

impl EvictionPolicy for Mru {
    fn name(&self) -> &'static str {
        "mru"
    }
    fn pick_victim(&mut self, candidates: &[RegionView]) -> usize {
        candidates
            .iter()
            .enumerate()
            .max_by_key(|(_, c)| c.last_used_tick)
            .map(|(i, _)| i)
            .expect("pick_victim on empty candidate set")
    }
}

/// First-in-first-out over load ticks.
#[derive(Debug, Default)]
pub struct Fifo;

impl EvictionPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }
    fn pick_victim(&mut self, candidates: &[RegionView]) -> usize {
        candidates
            .iter()
            .enumerate()
            .min_by_key(|(_, c)| c.loaded_at_tick)
            .map(|(i, _)| i)
            .expect("pick_victim on empty candidate set")
    }
}

/// Uniform random victim.
#[derive(Debug)]
pub struct RandomEvict {
    rng: Rng,
}

impl RandomEvict {
    pub fn new(seed: u64) -> RandomEvict {
        RandomEvict { rng: Rng::new(seed) }
    }
}

impl EvictionPolicy for RandomEvict {
    fn name(&self) -> &'static str {
        "random"
    }
    fn pick_victim(&mut self, candidates: &[RegionView]) -> usize {
        self.rng.below(candidates.len() as u64) as usize
    }
}

/// Belady's optimal offline policy: evict the role whose next use lies
/// furthest in the future. Requires the full dispatch trace up front —
/// usable only in the ablation harness, as the upper bound.
#[derive(Debug)]
pub struct BeladyOracle {
    trace: Vec<RoleId>,
    pos: usize,
}

impl BeladyOracle {
    pub fn new(trace: Vec<RoleId>) -> BeladyOracle {
        BeladyOracle { trace, pos: 0 }
    }

    fn next_use(&self, role: RoleId) -> Option<usize> {
        self.trace[self.pos..].iter().position(|r| *r == role)
    }
}

impl EvictionPolicy for BeladyOracle {
    fn name(&self) -> &'static str {
        "belady"
    }

    fn on_access(&mut self, role: RoleId) {
        // Advance past this access so next_use looks strictly ahead.
        debug_assert!(
            self.pos >= self.trace.len() || self.trace[self.pos] == role,
            "trace divergence: expected {:?} at {}, saw {:?}",
            self.trace.get(self.pos),
            self.pos,
            role
        );
        self.pos = (self.pos + 1).min(self.trace.len());
    }

    fn pick_victim(&mut self, candidates: &[RegionView]) -> usize {
        candidates
            .iter()
            .enumerate()
            .max_by_key(|(_, c)| self.next_use(c.role).unwrap_or(usize::MAX))
            .map(|(i, _)| i)
            .expect("pick_victim on empty candidate set")
    }
}

/// LRU extended with queued-demand awareness (async serving).
///
/// Victim selection is two-level: first prefer roles with *no* queued
/// demand, then break ties by least-recent use. A role the batcher has
/// requests queued for is only evicted when every candidate has demand
/// (in which case the least-demanded goes — it will be reloaded latest).
/// Demand table is an ordered map: no iteration-order nondeterminism can
/// leak into victim selection or debug output, which matters once several
/// policy instances run side by side in a multi-agent pool whose tests
/// demand reproducible placement.
#[derive(Debug, Default)]
pub struct QueueAwareLru {
    demand: BTreeMap<RoleId, u64>,
}

impl QueueAwareLru {
    pub fn new() -> QueueAwareLru {
        QueueAwareLru::default()
    }

    fn demand_for(&self, role: RoleId) -> u64 {
        self.demand.get(&role).copied().unwrap_or(0)
    }
}

impl EvictionPolicy for QueueAwareLru {
    fn name(&self) -> &'static str {
        "queue-aware"
    }

    fn on_demand(&mut self, role: RoleId, queued: u64) {
        if queued == 0 {
            self.demand.remove(&role);
        } else {
            self.demand.insert(role, queued);
        }
    }

    /// Halve every hint, dropping the ones that reach zero. Live lanes
    /// re-publish absolute depths before every flush, so decay only ever
    /// erodes *stale* entries; a dead signature's protection is gone
    /// within a few batches instead of pinning its region forever.
    fn decay_demand(&mut self) {
        self.demand.retain(|_, q| {
            *q /= 2;
            *q > 0
        });
    }

    fn pick_victim(&mut self, candidates: &[RegionView]) -> usize {
        candidates
            .iter()
            .enumerate()
            .min_by_key(|(_, c)| (self.demand_for(c.role), c.last_used_tick))
            .map(|(i, _)| i)
            .expect("pick_victim on empty candidate set")
    }
}

/// Name-indexed construction for CLI/bench parameter sweeps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    Lru,
    Mru,
    Fifo,
    Random,
    QueueAware,
}

impl PolicyKind {
    pub fn build(self, seed: u64) -> Box<dyn EvictionPolicy> {
        match self {
            PolicyKind::Lru => Box::new(Lru),
            PolicyKind::Mru => Box::new(Mru),
            PolicyKind::Fifo => Box::new(Fifo),
            PolicyKind::Random => Box::new(RandomEvict::new(seed)),
            PolicyKind::QueueAware => Box::new(QueueAwareLru::new()),
        }
    }

    pub fn parse(s: &str) -> Option<PolicyKind> {
        match s {
            "lru" => Some(PolicyKind::Lru),
            "mru" => Some(PolicyKind::Mru),
            "fifo" => Some(PolicyKind::Fifo),
            "random" => Some(PolicyKind::Random),
            "queue-aware" => Some(PolicyKind::QueueAware),
            _ => None,
        }
    }

    pub const ALL: [PolicyKind; 5] = [
        PolicyKind::Lru,
        PolicyKind::Mru,
        PolicyKind::Fifo,
        PolicyKind::Random,
        PolicyKind::QueueAware,
    ];
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(region_id: usize, role: u64, loaded: u64, used: u64) -> RegionView {
        RegionView {
            region_id,
            role: RoleId(role),
            loaded_at_tick: loaded,
            last_used_tick: used,
        }
    }

    #[test]
    fn lru_picks_least_recently_used() {
        let mut p = Lru;
        let c = [view(0, 1, 0, 9), view(1, 2, 0, 3), view(2, 3, 0, 7)];
        assert_eq!(p.pick_victim(&c), 1);
    }

    #[test]
    fn mru_picks_most_recently_used() {
        let mut p = Mru;
        let c = [view(0, 1, 0, 9), view(1, 2, 0, 3)];
        assert_eq!(p.pick_victim(&c), 0);
    }

    #[test]
    fn fifo_picks_oldest_load() {
        let mut p = Fifo;
        let c = [view(0, 1, 5, 100), view(1, 2, 2, 200), view(2, 3, 8, 1)];
        assert_eq!(p.pick_victim(&c), 1);
    }

    #[test]
    fn random_is_in_bounds_and_deterministic_per_seed() {
        let c = [view(0, 1, 0, 0), view(1, 2, 0, 0), view(2, 3, 0, 0)];
        let picks_a: Vec<usize> =
            (0..20).map(|_| RandomEvict::new(1).pick_victim(&c)).collect();
        let picks_b: Vec<usize> =
            (0..20).map(|_| RandomEvict::new(1).pick_victim(&c)).collect();
        assert_eq!(picks_a, picks_b);
        let mut p = RandomEvict::new(2);
        for _ in 0..50 {
            assert!(p.pick_victim(&c) < 3);
        }
    }

    #[test]
    fn belady_evicts_furthest_future_use() {
        // Trace: A B C A B ... with A,B resident and C incoming, victim
        // should be the one used furthest ahead.
        let (a, b, c) = (RoleId(1), RoleId(2), RoleId(3));
        let mut p = BeladyOracle::new(vec![a, b, c, b, a]);
        p.on_access(a);
        p.on_access(b);
        // now at trace[2] = c (miss): candidates a (next at 4), b (next 3).
        p.on_access(c);
        let cands = [view(0, 1, 0, 0), view(1, 2, 0, 1)];
        assert_eq!(p.pick_victim(&cands), 0, "a is used later than b");
    }

    #[test]
    fn belady_prefers_never_used_again() {
        let (a, b) = (RoleId(1), RoleId(2));
        let mut p = BeladyOracle::new(vec![a, b, a]);
        p.on_access(a);
        p.on_access(b);
        // a recurs, b never does.
        let cands = [view(0, 1, 0, 0), view(1, 2, 0, 1)];
        assert_eq!(p.pick_victim(&cands), 1);
    }

    #[test]
    fn queue_aware_spares_roles_with_demand() {
        let mut p = QueueAwareLru::new();
        // Role 1 is LRU-coldest but has queued requests; role 2 is warm but
        // idle — the idle one goes.
        p.on_demand(RoleId(1), 4);
        let c = [view(0, 1, 0, 1), view(1, 2, 0, 9)];
        assert_eq!(p.pick_victim(&c), 1, "demand outranks recency");
        // Hint cleared: falls back to plain LRU.
        p.on_demand(RoleId(1), 0);
        assert_eq!(p.pick_victim(&c), 0);
    }

    #[test]
    fn queue_aware_all_demanded_evicts_least_demanded() {
        let mut p = QueueAwareLru::new();
        p.on_demand(RoleId(1), 8);
        p.on_demand(RoleId(2), 2);
        let c = [view(0, 1, 0, 1), view(1, 2, 0, 9)];
        assert_eq!(p.pick_victim(&c), 1, "fewest queued requests goes");
    }

    #[test]
    fn queue_aware_demand_decays_instead_of_pinning_forever() {
        let mut p = QueueAwareLru::new();
        // A one-off spike on role 1, then its lane goes quiet (no more
        // publishes, so no explicit zero ever arrives).
        p.on_demand(RoleId(1), 4);
        let c = [view(0, 1, 0, 1), view(1, 2, 0, 9)];
        assert_eq!(p.pick_victim(&c), 1, "fresh hint protects role 1");
        // 4 -> 2 -> 1 -> 0: after three retired batches the stale hint
        // is gone and plain LRU resumes (role 1 is coldest).
        p.decay_demand();
        p.decay_demand();
        assert_eq!(p.pick_victim(&c), 1, "hint still protecting at 1");
        p.decay_demand();
        assert_eq!(p.demand_for(RoleId(1)), 0, "stale hint fully decayed");
        assert_eq!(p.pick_victim(&c), 0, "LRU order restored");
        // Decay on an already-empty table is a no-op.
        p.decay_demand();
        assert_eq!(p.pick_victim(&c), 0);
    }

    #[test]
    fn kind_parse_round_trip() {
        for k in PolicyKind::ALL {
            let name = k.build(0).name();
            assert_eq!(PolicyKind::parse(name), Some(k));
        }
        assert_eq!(PolicyKind::parse("belady"), None, "belady needs a trace");
    }
}
