//! The reconfiguration manager: role↔region binding with pluggable
//! eviction, driving the ICAP timing model and the hit/miss accounting
//! that Table II's "reconfiguration — if not configured" row reports.

use crate::fpga::bitstream::{Bitstream, RoleId};
use crate::fpga::icap::Icap;
use crate::fpga::region::PrRegion;
use crate::fpga::resources::ResourceVector;
use crate::hsa::error::{HsaError, Result};
use crate::reconfig::policy::{EvictionPolicy, RegionView};
use std::collections::HashMap;

/// Result of `ensure_loaded`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadOutcome {
    /// Role already resident; no PCAP traffic.
    Hit { region: usize },
    /// Role loaded into a free or victim region.
    Miss { region: usize, evicted: Option<RoleId>, reconfig_us: u64 },
}

impl LoadOutcome {
    pub fn region(&self) -> usize {
        match *self {
            LoadOutcome::Hit { region } => region,
            LoadOutcome::Miss { region, .. } => region,
        }
    }

    pub fn reconfig_us(&self) -> u64 {
        match *self {
            LoadOutcome::Hit { .. } => 0,
            LoadOutcome::Miss { reconfig_us, .. } => reconfig_us,
        }
    }
}

/// Aggregated counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReconfigStats {
    pub dispatches: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub reconfig_us_total: u64,
}

impl ReconfigStats {
    pub fn hit_rate(&self) -> f64 {
        if self.dispatches == 0 {
            0.0
        } else {
            self.hits as f64 / self.dispatches as f64
        }
    }

    /// Field-wise accumulation, for pooled rollups across a multi-agent
    /// FPGA pool (each agent keeps its own manager and stats; the session
    /// and serving reports sum them through here).
    pub fn accumulate(&mut self, other: &ReconfigStats) {
        self.dispatches += other.dispatches;
        self.hits += other.hits;
        self.misses += other.misses;
        self.evictions += other.evictions;
        self.reconfig_us_total += other.reconfig_us_total;
    }

    /// Sum of many per-agent stats (see [`ReconfigStats::accumulate`]).
    pub fn sum<'a>(parts: impl IntoIterator<Item = &'a ReconfigStats>) -> ReconfigStats {
        let mut total = ReconfigStats::default();
        for p in parts {
            total.accumulate(p);
        }
        total
    }
}

/// Manages which role occupies which PR region.
pub struct ReconfigManager {
    regions: Vec<PrRegion>,
    policy: Box<dyn EvictionPolicy>,
    icap: Icap,
    /// Monotonic access counter (the policy clock).
    tick: u64,
    /// role -> region for O(1) residency lookup.
    resident: HashMap<RoleId, usize>,
    stats: ReconfigStats,
}

impl ReconfigManager {
    pub fn new(regions: Vec<PrRegion>, policy: Box<dyn EvictionPolicy>, icap: Icap) -> Self {
        assert!(!regions.is_empty(), "at least one PR region required");
        ReconfigManager {
            regions,
            policy,
            icap,
            tick: 0,
            resident: HashMap::new(),
            stats: ReconfigStats::default(),
        }
    }

    /// Uniform regions helper: `n` regions of `capacity`.
    pub fn with_uniform_regions(
        n: usize,
        capacity: ResourceVector,
        policy: Box<dyn EvictionPolicy>,
        icap: Icap,
    ) -> Self {
        let regions = (0..n).map(|i| PrRegion::new(i, capacity)).collect();
        ReconfigManager::new(regions, policy, icap)
    }

    pub fn stats(&self) -> ReconfigStats {
        self.stats
    }

    pub fn regions(&self) -> &[PrRegion] {
        &self.regions
    }

    pub fn num_regions(&self) -> usize {
        self.regions.len()
    }

    /// Which region holds `role`, if resident.
    pub fn region_of(&self, role: RoleId) -> Option<usize> {
        self.resident.get(&role).copied()
    }

    /// Number of currently unoccupied PR regions (loading a role into one
    /// evicts nothing — the shard router prefers such agents for cold
    /// kernels).
    pub fn free_regions(&self) -> usize {
        self.regions.iter().filter(|r| r.is_free()).count()
    }

    /// Ensure `bitstream`'s role is resident; reconfigure (evicting if
    /// needed) on a miss. This is the dispatch-time fast path: a hit costs
    /// one hash lookup and two counter bumps.
    pub fn ensure_loaded(&mut self, bitstream: &Bitstream) -> Result<LoadOutcome> {
        self.tick += 1;
        self.stats.dispatches += 1;
        self.policy.on_access(bitstream.id);

        if let Some(&region) = self.resident.get(&bitstream.id) {
            self.regions[region].touch(self.tick);
            self.stats.hits += 1;
            return Ok(LoadOutcome::Hit { region });
        }

        // Miss: find a free region, else ask the policy for a victim.
        self.stats.misses += 1;
        let region_idx = match self.regions.iter().position(|r| {
            r.is_free() && bitstream.resources.fits_in(&r.capacity)
        }) {
            Some(i) => i,
            None => self.evict_for(bitstream)?,
        };

        let us = self.icap.reconfigure(bitstream.bytes);
        self.stats.reconfig_us_total += us;
        let evicted = self.regions[region_idx].evict();
        if let Some(old) = evicted {
            self.resident.remove(&old);
        }
        self.regions[region_idx].load(bitstream.id, self.tick);
        self.regions[region_idx].touch(self.tick);
        self.resident.insert(bitstream.id, region_idx);
        Ok(LoadOutcome::Miss {
            region: region_idx,
            evicted,
            reconfig_us: us,
        })
    }

    fn evict_for(&mut self, bitstream: &Bitstream) -> Result<usize> {
        let candidates: Vec<RegionView> = self
            .regions
            .iter()
            .filter(|r| bitstream.resources.fits_in(&r.capacity))
            .map(|r| RegionView {
                region_id: r.id,
                role: r.loaded.expect("occupied region without role"),
                loaded_at_tick: r.loaded_at_tick,
                last_used_tick: r.last_used_tick,
            })
            .collect();
        if candidates.is_empty() {
            return Err(HsaError::Runtime(format!(
                "role '{}' ({}) fits no PR region",
                bitstream.name, bitstream.resources
            )));
        }
        let victim = self.policy.pick_victim(&candidates);
        assert!(victim < candidates.len(), "policy returned out-of-range victim");
        self.stats.evictions += 1;
        Ok(candidates[victim].region_id)
    }

    /// Forward a queued-demand hint from the serving layer to the policy
    /// (see `EvictionPolicy::on_demand`). No-op for demand-blind policies.
    pub fn demand_hint(&mut self, role: RoleId, queued: u64) {
        self.policy.on_demand(role, queued);
    }

    /// ICAP accounting passthrough (total modeled reconfiguration time).
    pub fn icap(&self) -> &Icap {
        &self.icap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::datapath::{DatapathSpec, RoleOp};
    use crate::reconfig::policy::{Fifo, Lru};

    fn spec() -> DatapathSpec {
        DatapathSpec {
            name: "t",
            op: RoleOp::Stream { elements: 8, ops_per_element: 2 },
            macs_per_cycle: 1,
            ii: 1,
            pipeline_depth: 0,
            burst_bytes: 64,
            burst_overhead_cycles: 0,
            barriers_per_pass: 0,
            barrier_stall_cycles: 0,
            clock_mhz: 100,
        }
    }

    fn bs(name: &str) -> Bitstream {
        Bitstream::new(name, 1000, ResourceVector::new(10, 10, 1, 1), spec())
    }

    fn mgr(n: usize) -> ReconfigManager {
        ReconfigManager::with_uniform_regions(
            n,
            ResourceVector::new(100, 100, 10, 10),
            Box::new(Lru),
            Icap::new(1000.0, 0),
        )
    }

    #[test]
    fn first_dispatch_is_miss_then_hits() {
        let mut m = mgr(2);
        let a = bs("a");
        assert!(matches!(
            m.ensure_loaded(&a).unwrap(),
            LoadOutcome::Miss { evicted: None, .. }
        ));
        assert!(matches!(m.ensure_loaded(&a).unwrap(), LoadOutcome::Hit { .. }));
        let s = m.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 1, 0));
    }

    #[test]
    fn fills_free_regions_before_evicting() {
        let mut m = mgr(2);
        let (a, b) = (bs("a"), bs("b"));
        m.ensure_loaded(&a).unwrap();
        let out = m.ensure_loaded(&b).unwrap();
        assert!(matches!(out, LoadOutcome::Miss { evicted: None, .. }));
        assert_eq!(m.stats().evictions, 0);
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut m = mgr(2);
        let (a, b, c) = (bs("a"), bs("b"), bs("c"));
        m.ensure_loaded(&a).unwrap();
        m.ensure_loaded(&b).unwrap();
        m.ensure_loaded(&a).unwrap(); // a is now MRU
        let out = m.ensure_loaded(&c).unwrap();
        match out {
            LoadOutcome::Miss { evicted: Some(victim), .. } => assert_eq!(victim, b.id),
            o => panic!("expected eviction, got {o:?}"),
        }
        assert_eq!(m.region_of(b.id), None);
        assert!(m.region_of(a.id).is_some());
    }

    #[test]
    fn fifo_evicts_oldest_load_even_if_recently_used() {
        let mut m = ReconfigManager::with_uniform_regions(
            2,
            ResourceVector::new(100, 100, 10, 10),
            Box::new(Fifo),
            Icap::new(1000.0, 0),
        );
        let (a, b, c) = (bs("a"), bs("b"), bs("c"));
        m.ensure_loaded(&a).unwrap();
        m.ensure_loaded(&b).unwrap();
        m.ensure_loaded(&a).unwrap(); // touch a; FIFO ignores it
        let out = m.ensure_loaded(&c).unwrap();
        match out {
            LoadOutcome::Miss { evicted: Some(victim), .. } => assert_eq!(victim, a.id),
            o => panic!("expected eviction, got {o:?}"),
        }
    }

    #[test]
    fn reconfig_time_accumulates_only_on_miss() {
        let mut m = mgr(1);
        let a = bs("a");
        m.ensure_loaded(&a).unwrap();
        m.ensure_loaded(&a).unwrap();
        m.ensure_loaded(&a).unwrap();
        assert_eq!(m.stats().reconfig_us_total, 1); // 1000 B / 1000 B-per-µs
        assert_eq!(m.icap().total_reconfigs(), 1);
    }

    #[test]
    fn demand_hint_steers_queue_aware_eviction() {
        let mut m = ReconfigManager::with_uniform_regions(
            2,
            ResourceVector::new(100, 100, 10, 10),
            Box::new(crate::reconfig::policy::QueueAwareLru::new()),
            Icap::new(1000.0, 0),
        );
        let (a, b, c) = (bs("a"), bs("b"), bs("c"));
        m.ensure_loaded(&a).unwrap();
        m.ensure_loaded(&b).unwrap();
        // a is the LRU victim, but the batcher has requests queued on it.
        m.demand_hint(a.id, 5);
        match m.ensure_loaded(&c).unwrap() {
            LoadOutcome::Miss { evicted: Some(victim), .. } => assert_eq!(victim, b.id),
            o => panic!("expected eviction, got {o:?}"),
        }
        assert!(m.region_of(a.id).is_some(), "demanded role stays resident");
    }

    #[test]
    fn oversized_role_is_rejected() {
        let mut m = mgr(1);
        let huge = Bitstream::new(
            "huge",
            1000,
            ResourceVector::new(10_000, 10, 1, 1),
            spec(),
        );
        assert!(m.ensure_loaded(&huge).is_err());
    }

    #[test]
    fn residency_map_matches_regions() {
        let mut m = mgr(3);
        let roles: Vec<Bitstream> = (0..5).map(|i| bs(&format!("r{i}"))).collect();
        for r in &roles {
            m.ensure_loaded(r).unwrap();
        }
        // Invariant: every occupied region appears in the residency map,
        // and vice versa.
        let occupied: Vec<(usize, RoleId)> = m
            .regions()
            .iter()
            .filter_map(|r| r.loaded.map(|ro| (r.id, ro)))
            .collect();
        assert_eq!(occupied.len(), 3);
        for (rid, role) in occupied {
            assert_eq!(m.region_of(role), Some(rid));
        }
    }

    #[test]
    fn thrash_working_set_larger_than_regions() {
        let mut m = mgr(2);
        let roles: Vec<Bitstream> = (0..3).map(|i| bs(&format!("r{i}"))).collect();
        // Cyclic access over 3 roles with 2 regions under LRU: every access
        // after warmup is a miss (the classic LRU pathology).
        for _ in 0..3 {
            for r in &roles {
                m.ensure_loaded(r).unwrap();
            }
        }
        let s = m.stats();
        assert_eq!(s.dispatches, 9);
        assert_eq!(s.misses, 9, "cyclic(3) over 2 LRU regions never hits");
    }
}
