//! The reconfiguration manager: role↔region binding with pluggable
//! eviction, driving the ICAP timing model and the hit/miss accounting
//! that Table II's "reconfiguration — if not configured" row reports.

use crate::fpga::bitstream::{Bitstream, RoleId};
use crate::fpga::icap::{Icap, IcapTransaction};
use crate::fpga::region::{PrRegion, RegionState};
use crate::fpga::resources::ResourceVector;
use crate::hsa::error::{HsaError, Result};
use crate::reconfig::policy::{EvictionPolicy, RegionView};
use crate::reconfig::scheduler::{CostClass, Prefetch};
use std::collections::{BTreeSet, HashMap};

/// Result of `ensure_loaded`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadOutcome {
    /// Role already resident; `wait_us` is the residual ICAP transfer
    /// time if the role's own prefetch was still streaming (0 when the
    /// region was fully `Ready` — the common case, and always 0 when
    /// prefetching is off).
    Hit { region: usize, wait_us: u64 },
    /// Role loaded into a free or victim region.
    Miss { region: usize, evicted: Option<RoleId>, reconfig_us: u64 },
}

impl LoadOutcome {
    pub fn region(&self) -> usize {
        match *self {
            LoadOutcome::Hit { region, .. } => region,
            LoadOutcome::Miss { region, .. } => region,
        }
    }

    pub fn reconfig_us(&self) -> u64 {
        match *self {
            LoadOutcome::Hit { .. } => 0,
            LoadOutcome::Miss { reconfig_us, .. } => reconfig_us,
        }
    }

    /// ICAP time this dispatch actually waited on its critical path:
    /// the full (possibly queued) reconfiguration on a miss, the
    /// residual transfer on a hit-under-prefetch, zero on a clean hit.
    pub fn stall_us(&self) -> u64 {
        match *self {
            LoadOutcome::Hit { wait_us, .. } => wait_us,
            LoadOutcome::Miss { reconfig_us, .. } => reconfig_us,
        }
    }

    /// Stall attribution for trace events and the slow-request log:
    /// `"hit"` (resident, nothing waited), `"prefetch-wait"` (resident
    /// but its own prefetch was still streaming — the stall is the
    /// residual transfer), `"miss"` (reactive reconfiguration on the
    /// dispatch critical path).
    pub fn attribution(&self) -> &'static str {
        match *self {
            LoadOutcome::Hit { wait_us: 0, .. } => "hit",
            LoadOutcome::Hit { .. } => "prefetch-wait",
            LoadOutcome::Miss { .. } => "miss",
        }
    }
}

/// Aggregated counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReconfigStats {
    pub dispatches: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub reconfig_us_total: u64,
    /// Background loads started by `try_prefetch`.
    pub prefetches: u64,
    /// Prefetched roles that were later dispatched (useful prefetches).
    pub prefetch_hits: u64,
    /// Prefetched roles evicted before any dispatch used them.
    pub prefetch_wasted: u64,
    /// ICAP time hidden behind compute (transfer finished or progressed
    /// while other regions executed dispatches).
    pub overlapped_us: u64,
    /// ICAP time exposed on the dispatch critical path (reactive misses
    /// plus residual waits on in-flight prefetches).
    pub stall_us: u64,
}

impl ReconfigStats {
    pub fn hit_rate(&self) -> f64 {
        if self.dispatches == 0 {
            0.0
        } else {
            self.hits as f64 / self.dispatches as f64
        }
    }

    /// Fraction of started prefetches that a dispatch later used.
    /// 0.0 on a fresh agent (no division by zero).
    pub fn prefetch_hit_rate(&self) -> f64 {
        if self.prefetches == 0 {
            0.0
        } else {
            self.prefetch_hits as f64 / self.prefetches as f64
        }
    }

    /// Field-wise accumulation, for pooled rollups across a multi-agent
    /// FPGA pool (each agent keeps its own manager and stats; the session
    /// and serving reports sum them through here).
    pub fn accumulate(&mut self, other: &ReconfigStats) {
        self.dispatches += other.dispatches;
        self.hits += other.hits;
        self.misses += other.misses;
        self.evictions += other.evictions;
        self.reconfig_us_total += other.reconfig_us_total;
        self.prefetches += other.prefetches;
        self.prefetch_hits += other.prefetch_hits;
        self.prefetch_wasted += other.prefetch_wasted;
        self.overlapped_us += other.overlapped_us;
        self.stall_us += other.stall_us;
    }

    /// Sum of many per-agent stats (see [`ReconfigStats::accumulate`]).
    pub fn sum<'a>(parts: impl IntoIterator<Item = &'a ReconfigStats>) -> ReconfigStats {
        let mut total = ReconfigStats::default();
        for p in parts {
            total.accumulate(p);
        }
        total
    }
}

/// Manages which role occupies which PR region.
pub struct ReconfigManager {
    regions: Vec<PrRegion>,
    policy: Box<dyn EvictionPolicy>,
    icap: Icap,
    /// Monotonic access counter (the policy clock).
    tick: u64,
    /// role -> region for O(1) residency lookup.
    resident: HashMap<RoleId, usize>,
    stats: ReconfigStats,
    /// Virtual time in µs, advanced only by modeled durations (ICAP
    /// waits here, kernel execution via `advance_clock`) — never wall
    /// time, so twin managers fed the same call sequence agree exactly.
    clock_us: u64,
    /// The single ICAP port's in-flight background transaction, if any
    /// (dispatch-path reconfigurations complete synchronously).
    pending: Option<IcapTransaction>,
    /// Prefetched roles not yet used by any dispatch, for the
    /// `prefetch_hits` / `prefetch_wasted` accounting.
    prefetched_unused: BTreeSet<RoleId>,
}

impl ReconfigManager {
    pub fn new(regions: Vec<PrRegion>, policy: Box<dyn EvictionPolicy>, icap: Icap) -> Self {
        assert!(!regions.is_empty(), "at least one PR region required");
        ReconfigManager {
            regions,
            policy,
            icap,
            tick: 0,
            resident: HashMap::new(),
            stats: ReconfigStats::default(),
            clock_us: 0,
            pending: None,
            prefetched_unused: BTreeSet::new(),
        }
    }

    /// Uniform regions helper: `n` regions of `capacity`.
    pub fn with_uniform_regions(
        n: usize,
        capacity: ResourceVector,
        policy: Box<dyn EvictionPolicy>,
        icap: Icap,
    ) -> Self {
        let regions = (0..n).map(|i| PrRegion::new(i, capacity)).collect();
        ReconfigManager::new(regions, policy, icap)
    }

    pub fn stats(&self) -> ReconfigStats {
        self.stats
    }

    pub fn regions(&self) -> &[PrRegion] {
        &self.regions
    }

    pub fn num_regions(&self) -> usize {
        self.regions.len()
    }

    /// Which region holds `role`, if resident.
    pub fn region_of(&self, role: RoleId) -> Option<usize> {
        self.resident.get(&role).copied()
    }

    /// Number of currently unoccupied PR regions (loading a role into one
    /// evicts nothing — the shard router prefers such agents for cold
    /// kernels).
    pub fn free_regions(&self) -> usize {
        self.regions.iter().filter(|r| r.is_free()).count()
    }

    /// Ensure `bitstream`'s role is resident; reconfigure (evicting if
    /// needed) on a miss. This is the dispatch-time fast path: a hit costs
    /// one hash lookup and two counter bumps.
    pub fn ensure_loaded(&mut self, bitstream: &Bitstream) -> Result<LoadOutcome> {
        self.tick += 1;
        self.stats.dispatches += 1;
        self.policy.on_access(bitstream.id);
        self.settle();

        if let Some(&region) = self.resident.get(&bitstream.id) {
            // If this role's own prefetch is still streaming, the
            // dispatch pays only the residual transfer time.
            let mut wait_us = 0;
            if self.pending.map(|t| t.role) == Some(bitstream.id) {
                wait_us = self.drain_pending();
            }
            if self.prefetched_unused.remove(&bitstream.id) {
                self.stats.prefetch_hits += 1;
            }
            self.regions[region].touch(self.tick);
            self.stats.hits += 1;
            return Ok(LoadOutcome::Hit { region, wait_us });
        }

        // Miss: the single ICAP port must finish any in-flight prefetch
        // before this reconfiguration can start.
        self.stats.misses += 1;
        let icap_wait = self.drain_pending();

        // Find a free region, else ask the policy for a victim.
        let region_idx = match self.regions.iter().position(|r| {
            r.is_free() && bitstream.resources.fits_in(&r.capacity)
        }) {
            Some(i) => i,
            None => self.evict_for(bitstream)?,
        };

        let us = self.icap.reconfigure(bitstream.bytes);
        self.stats.reconfig_us_total += us;
        self.stats.stall_us += us;
        self.clock_us += us;
        let evicted = self.regions[region_idx].evict();
        if let Some(old) = evicted {
            self.resident.remove(&old);
            if self.prefetched_unused.remove(&old) {
                self.stats.prefetch_wasted += 1;
            }
        }
        self.regions[region_idx].load(bitstream.id, self.tick);
        self.regions[region_idx].touch(self.tick);
        self.resident.insert(bitstream.id, region_idx);
        Ok(LoadOutcome::Miss {
            region: region_idx,
            evicted,
            reconfig_us: us + icap_wait,
        })
    }

    /// Non-blocking background load: start programming `bitstream` into
    /// a free (or safely evictable) region without touching the
    /// dispatch accounting. The transfer completes on the virtual clock
    /// (`advance_clock`) `reconfig_us` later, overlapped with compute on
    /// the other regions — the caller is the prefetch scheduler
    /// ([`crate::reconfig::scheduler::PrefetchScheduler`]).
    ///
    /// Safety rules, in order:
    /// * the single ICAP port takes one transaction at a time
    ///   ([`Prefetch::IcapBusy`] if occupied);
    /// * a free region is claimed only while more than
    ///   `min_free_regions` remain free;
    /// * an eviction victim must be occupied, fully configured, fit the
    ///   bitstream, and not host any role in `protected` (in-flight or
    ///   sooner-needed kernels) — otherwise [`Prefetch::NoSafeRegion`].
    ///
    /// The eviction policy's access clock is *not* advanced: a prefetch
    /// is not a dispatch, so LRU ordering and the Belady oracle's trace
    /// position stay aligned with real accesses.
    pub fn try_prefetch(
        &mut self,
        bitstream: &Bitstream,
        protected: &[RoleId],
        min_free_regions: usize,
        deadline_hint: u64,
    ) -> Prefetch {
        self.settle();
        if let Some(txn) = self.pending {
            if txn.role == bitstream.id {
                return Prefetch::InFlight;
            }
        }
        if self.resident.contains_key(&bitstream.id) {
            return Prefetch::Resident;
        }
        if self.pending.is_some() {
            return Prefetch::IcapBusy;
        }

        let free_fitting = self
            .regions
            .iter()
            .position(|r| r.is_free() && bitstream.resources.fits_in(&r.capacity));
        let region_idx = match free_fitting {
            Some(i) if self.free_regions() > min_free_regions => i,
            _ => {
                let candidates: Vec<RegionView> = self
                    .regions
                    .iter()
                    .filter(|r| {
                        !r.is_free()
                            && !r.is_configuring()
                            && bitstream.resources.fits_in(&r.capacity)
                            && r.loaded.is_some_and(|role| !protected.contains(&role))
                    })
                    .map(|r| RegionView {
                        region_id: r.id,
                        role: r.loaded.expect("occupied region without role"),
                        loaded_at_tick: r.loaded_at_tick,
                        last_used_tick: r.last_used_tick,
                    })
                    .collect();
                if candidates.is_empty() {
                    return Prefetch::NoSafeRegion;
                }
                let victim = self.policy.pick_victim(&candidates);
                assert!(victim < candidates.len(), "policy returned out-of-range victim");
                candidates[victim].region_id
            }
        };

        let us = self.icap.reconfigure(bitstream.bytes);
        self.stats.reconfig_us_total += us;
        self.stats.prefetches += 1;
        let evicted = self.regions[region_idx].evict();
        if let Some(old) = evicted {
            self.stats.evictions += 1;
            self.resident.remove(&old);
            if self.prefetched_unused.remove(&old) {
                self.stats.prefetch_wasted += 1;
            }
        }
        self.regions[region_idx].load(bitstream.id, self.tick);
        self.regions[region_idx].state = RegionState::Configuring;
        self.resident.insert(bitstream.id, region_idx);
        self.prefetched_unused.insert(bitstream.id);
        self.pending = Some(IcapTransaction {
            role: bitstream.id,
            region: region_idx,
            reconfig_us: us,
            ready_at_us: self.clock_us + us,
            deadline_hint,
        });
        Prefetch::Started { region: region_idx, reconfig_us: us }
    }

    /// Coarse dispatch-cost probe for the router (cheapest first): is
    /// `role` resident (or its transfer already in flight), loadable
    /// into a free region, loadable only by evicting, or queued behind
    /// a foreign ICAP transaction?
    pub fn cost_of(&mut self, role: RoleId) -> CostClass {
        self.settle();
        if let Some(txn) = self.pending {
            if txn.role == role {
                return CostClass::Resident;
            }
        }
        if self.resident.contains_key(&role) {
            return CostClass::Resident;
        }
        if self.pending.is_some() {
            return CostClass::IcapBusy;
        }
        if self.free_regions() > 0 {
            CostClass::FreeRegion
        } else {
            CostClass::MustEvict
        }
    }

    /// Advance the virtual clock by a modeled compute duration (called
    /// by the agent after each kernel execution); any pending ICAP
    /// transaction that finishes inside the interval settles, its
    /// transfer time fully hidden behind the compute.
    pub fn advance_clock(&mut self, us: u64) {
        self.clock_us += us;
        self.settle();
    }

    /// Virtual time in µs (modeled durations only; see `advance_clock`).
    pub fn clock_us(&self) -> u64 {
        self.clock_us
    }

    /// Is the single ICAP port still streaming a transaction?
    pub fn icap_busy(&mut self) -> bool {
        self.settle();
        self.pending.is_some()
    }

    /// The in-flight background transaction, if any (after settling).
    pub fn pending_transaction(&mut self) -> Option<IcapTransaction> {
        self.settle();
        self.pending
    }

    /// Retire the pending transaction if the virtual clock has reached
    /// its completion time: the transfer was fully hidden behind
    /// compute, the region becomes `Ready`.
    fn settle(&mut self) {
        if let Some(txn) = self.pending {
            if txn.ready_at_us <= self.clock_us {
                self.stats.overlapped_us += txn.reconfig_us;
                self.regions[txn.region].state = RegionState::Ready;
                self.pending = None;
            }
        }
    }

    /// Block on the pending transaction (dispatch needs the ICAP port or
    /// the transferring region *now*): the elapsed part of the transfer
    /// counts as overlapped, the remainder as stall. Returns the wait.
    fn drain_pending(&mut self) -> u64 {
        self.settle();
        match self.pending.take() {
            None => 0,
            Some(txn) => {
                let wait = txn.remaining_us(self.clock_us);
                self.stats.stall_us += wait;
                self.stats.overlapped_us += txn.reconfig_us - wait;
                self.clock_us += wait;
                self.regions[txn.region].state = RegionState::Ready;
                wait
            }
        }
    }

    fn evict_for(&mut self, bitstream: &Bitstream) -> Result<usize> {
        let candidates: Vec<RegionView> = self
            .regions
            .iter()
            .filter(|r| bitstream.resources.fits_in(&r.capacity))
            .map(|r| RegionView {
                region_id: r.id,
                role: r.loaded.expect("occupied region without role"),
                loaded_at_tick: r.loaded_at_tick,
                last_used_tick: r.last_used_tick,
            })
            .collect();
        if candidates.is_empty() {
            return Err(HsaError::Runtime(format!(
                "role '{}' ({}) fits no PR region",
                bitstream.name, bitstream.resources
            )));
        }
        let victim = self.policy.pick_victim(&candidates);
        assert!(victim < candidates.len(), "policy returned out-of-range victim");
        self.stats.evictions += 1;
        Ok(candidates[victim].region_id)
    }

    /// Forward a queued-demand hint from the serving layer to the policy
    /// (see `EvictionPolicy::on_demand`). No-op for demand-blind policies.
    pub fn demand_hint(&mut self, role: RoleId, queued: u64) {
        self.policy.on_demand(role, queued);
    }

    /// Age the policy's demand hints by one retired serving batch (see
    /// `EvictionPolicy::decay_demand`). No-op for demand-blind policies.
    pub fn decay_demand(&mut self) {
        self.policy.decay_demand();
    }

    /// ICAP accounting passthrough (total modeled reconfiguration time).
    pub fn icap(&self) -> &Icap {
        &self.icap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::datapath::{DatapathSpec, RoleOp};
    use crate::reconfig::policy::{Fifo, Lru};

    fn spec() -> DatapathSpec {
        DatapathSpec {
            name: "t",
            op: RoleOp::Stream { elements: 8, ops_per_element: 2 },
            macs_per_cycle: 1,
            ii: 1,
            pipeline_depth: 0,
            burst_bytes: 64,
            burst_overhead_cycles: 0,
            barriers_per_pass: 0,
            barrier_stall_cycles: 0,
            clock_mhz: 100,
        }
    }

    fn bs(name: &str) -> Bitstream {
        Bitstream::new(name, 1000, ResourceVector::new(10, 10, 1, 1), spec())
    }

    fn mgr(n: usize) -> ReconfigManager {
        ReconfigManager::with_uniform_regions(
            n,
            ResourceVector::new(100, 100, 10, 10),
            Box::new(Lru),
            Icap::new(1000.0, 0),
        )
    }

    #[test]
    fn first_dispatch_is_miss_then_hits() {
        let mut m = mgr(2);
        let a = bs("a");
        assert!(matches!(
            m.ensure_loaded(&a).unwrap(),
            LoadOutcome::Miss { evicted: None, .. }
        ));
        assert!(matches!(m.ensure_loaded(&a).unwrap(), LoadOutcome::Hit { .. }));
        let s = m.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 1, 0));
    }

    #[test]
    fn fills_free_regions_before_evicting() {
        let mut m = mgr(2);
        let (a, b) = (bs("a"), bs("b"));
        m.ensure_loaded(&a).unwrap();
        let out = m.ensure_loaded(&b).unwrap();
        assert!(matches!(out, LoadOutcome::Miss { evicted: None, .. }));
        assert_eq!(m.stats().evictions, 0);
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut m = mgr(2);
        let (a, b, c) = (bs("a"), bs("b"), bs("c"));
        m.ensure_loaded(&a).unwrap();
        m.ensure_loaded(&b).unwrap();
        m.ensure_loaded(&a).unwrap(); // a is now MRU
        let out = m.ensure_loaded(&c).unwrap();
        match out {
            LoadOutcome::Miss { evicted: Some(victim), .. } => assert_eq!(victim, b.id),
            o => panic!("expected eviction, got {o:?}"),
        }
        assert_eq!(m.region_of(b.id), None);
        assert!(m.region_of(a.id).is_some());
    }

    #[test]
    fn fifo_evicts_oldest_load_even_if_recently_used() {
        let mut m = ReconfigManager::with_uniform_regions(
            2,
            ResourceVector::new(100, 100, 10, 10),
            Box::new(Fifo),
            Icap::new(1000.0, 0),
        );
        let (a, b, c) = (bs("a"), bs("b"), bs("c"));
        m.ensure_loaded(&a).unwrap();
        m.ensure_loaded(&b).unwrap();
        m.ensure_loaded(&a).unwrap(); // touch a; FIFO ignores it
        let out = m.ensure_loaded(&c).unwrap();
        match out {
            LoadOutcome::Miss { evicted: Some(victim), .. } => assert_eq!(victim, a.id),
            o => panic!("expected eviction, got {o:?}"),
        }
    }

    #[test]
    fn reconfig_time_accumulates_only_on_miss() {
        let mut m = mgr(1);
        let a = bs("a");
        m.ensure_loaded(&a).unwrap();
        m.ensure_loaded(&a).unwrap();
        m.ensure_loaded(&a).unwrap();
        assert_eq!(m.stats().reconfig_us_total, 1); // 1000 B / 1000 B-per-µs
        assert_eq!(m.icap().total_reconfigs(), 1);
    }

    #[test]
    fn demand_hint_steers_queue_aware_eviction() {
        let mut m = ReconfigManager::with_uniform_regions(
            2,
            ResourceVector::new(100, 100, 10, 10),
            Box::new(crate::reconfig::policy::QueueAwareLru::new()),
            Icap::new(1000.0, 0),
        );
        let (a, b, c) = (bs("a"), bs("b"), bs("c"));
        m.ensure_loaded(&a).unwrap();
        m.ensure_loaded(&b).unwrap();
        // a is the LRU victim, but the batcher has requests queued on it.
        m.demand_hint(a.id, 5);
        match m.ensure_loaded(&c).unwrap() {
            LoadOutcome::Miss { evicted: Some(victim), .. } => assert_eq!(victim, b.id),
            o => panic!("expected eviction, got {o:?}"),
        }
        assert!(m.region_of(a.id).is_some(), "demanded role stays resident");
    }

    #[test]
    fn oversized_role_is_rejected() {
        let mut m = mgr(1);
        let huge = Bitstream::new(
            "huge",
            1000,
            ResourceVector::new(10_000, 10, 1, 1),
            spec(),
        );
        assert!(m.ensure_loaded(&huge).is_err());
    }

    #[test]
    fn residency_map_matches_regions() {
        let mut m = mgr(3);
        let roles: Vec<Bitstream> = (0..5).map(|i| bs(&format!("r{i}"))).collect();
        for r in &roles {
            m.ensure_loaded(r).unwrap();
        }
        // Invariant: every occupied region appears in the residency map,
        // and vice versa.
        let occupied: Vec<(usize, RoleId)> = m
            .regions()
            .iter()
            .filter_map(|r| r.loaded.map(|ro| (r.id, ro)))
            .collect();
        assert_eq!(occupied.len(), 3);
        for (rid, role) in occupied {
            assert_eq!(m.region_of(role), Some(rid));
        }
    }

    #[test]
    fn thrash_working_set_larger_than_regions() {
        let mut m = mgr(2);
        let roles: Vec<Bitstream> = (0..3).map(|i| bs(&format!("r{i}"))).collect();
        // Cyclic access over 3 roles with 2 regions under LRU: every access
        // after warmup is a miss (the classic LRU pathology).
        for _ in 0..3 {
            for r in &roles {
                m.ensure_loaded(r).unwrap();
            }
        }
        let s = m.stats();
        assert_eq!(s.dispatches, 9);
        assert_eq!(s.misses, 9, "cyclic(3) over 2 LRU regions never hits");
    }

    #[test]
    fn hit_rate_is_zero_on_fresh_agent() {
        // A fresh agent scraped by /metrics before its first request
        // must report 0.0, not NaN (division by zero).
        let m = mgr(2);
        assert_eq!(m.stats().hit_rate(), 0.0);
        assert_eq!(m.stats().prefetch_hit_rate(), 0.0);
        assert_eq!(ReconfigStats::default().hit_rate(), 0.0);
    }

    #[test]
    fn prefetch_loads_free_region_without_dispatch_accounting() {
        let mut m = mgr(2);
        let a = bs("a");
        let out = m.try_prefetch(&a, &[], 0, 1);
        assert!(matches!(out, Prefetch::Started { region: 0, reconfig_us: 1 }));
        assert!(m.regions()[0].is_configuring());
        assert!(m.icap_busy());
        let s = m.stats();
        assert_eq!((s.prefetches, s.dispatches, s.misses, s.hits), (1, 0, 0, 0));
        // Compute elsewhere hides the whole transfer.
        m.advance_clock(5);
        assert!(!m.icap_busy());
        assert_eq!(m.stats().overlapped_us, 1);
        // The dispatch that follows is a clean hit, credited to prefetch.
        let out = m.ensure_loaded(&a).unwrap();
        assert_eq!(out, LoadOutcome::Hit { region: 0, wait_us: 0 });
        let s = m.stats();
        assert_eq!((s.hits, s.prefetch_hits, s.stall_us), (1, 1, 0));
    }

    #[test]
    fn dispatch_mid_prefetch_pays_only_the_residual_transfer() {
        // 1000-byte roles at 100 B/µs: 10 µs per reconfiguration.
        let mut m = ReconfigManager::with_uniform_regions(
            2,
            ResourceVector::new(100, 100, 10, 10),
            Box::new(Lru),
            Icap::new(100.0, 0),
        );
        let a = bs("a");
        assert!(matches!(m.try_prefetch(&a, &[], 0, 0), Prefetch::Started { .. }));
        m.advance_clock(4); // 4 of 10 µs hidden behind compute
        let out = m.ensure_loaded(&a).unwrap();
        assert_eq!(out, LoadOutcome::Hit { region: 0, wait_us: 6 });
        assert_eq!(out.stall_us(), 6);
        let s = m.stats();
        assert_eq!((s.overlapped_us, s.stall_us, s.prefetch_hits), (4, 6, 1));
        assert_eq!(m.clock_us(), 10);
    }

    #[test]
    fn single_icap_port_serializes_prefetches() {
        let mut m = mgr(3);
        let (a, b) = (bs("a"), bs("b"));
        assert!(matches!(m.try_prefetch(&a, &[], 0, 0), Prefetch::Started { .. }));
        assert_eq!(m.try_prefetch(&b, &[], 0, 1), Prefetch::IcapBusy);
        assert_eq!(m.try_prefetch(&a, &[], 0, 0), Prefetch::InFlight);
        m.advance_clock(100);
        assert_eq!(m.try_prefetch(&a, &[], 0, 0), Prefetch::Resident);
        assert!(matches!(m.try_prefetch(&b, &[], 0, 0), Prefetch::Started { .. }));
    }

    #[test]
    fn prefetch_never_evicts_protected_roles() {
        let mut m = mgr(1);
        let (a, b) = (bs("a"), bs("b"));
        m.ensure_loaded(&a).unwrap();
        // The only region hosts a protected (in-flight/sooner) role.
        assert_eq!(m.try_prefetch(&b, &[a.id], 0, 1), Prefetch::NoSafeRegion);
        assert!(m.region_of(a.id).is_some());
        // Unprotected, the same prefetch evicts it.
        assert!(matches!(m.try_prefetch(&b, &[], 0, 1), Prefetch::Started { .. }));
        assert_eq!(m.region_of(a.id), None);
        assert_eq!(m.stats().evictions, 1);
    }

    #[test]
    fn min_free_regions_keeps_headroom() {
        let mut m = mgr(2);
        let a = bs("a");
        // Both regions free, but one must stay free: with no occupied
        // region to evict either, the prefetch is declined.
        assert_eq!(m.try_prefetch(&a, &[], 2, 0), Prefetch::NoSafeRegion);
        // Headroom 1: the other free region is claimable.
        assert!(matches!(m.try_prefetch(&a, &[], 1, 0), Prefetch::Started { .. }));
    }

    #[test]
    fn overwritten_unused_prefetch_counts_as_wasted() {
        let mut m = mgr(1);
        let (a, b) = (bs("a"), bs("b"));
        assert!(matches!(m.try_prefetch(&a, &[], 0, 0), Prefetch::Started { .. }));
        m.advance_clock(100);
        assert!(matches!(m.try_prefetch(&b, &[], 0, 0), Prefetch::Started { .. }));
        let s = m.stats();
        assert_eq!((s.prefetches, s.prefetch_wasted, s.prefetch_hits), (2, 1, 0));
    }

    #[test]
    fn miss_queues_behind_the_pending_transaction() {
        // 10 µs per reconfiguration; a dispatch miss for role b must
        // wait for a's in-flight prefetch (single ICAP port), then pay
        // its own transfer — and a's region ends up Ready, not stuck.
        let mut m = ReconfigManager::with_uniform_regions(
            2,
            ResourceVector::new(100, 100, 10, 10),
            Box::new(Lru),
            Icap::new(100.0, 0),
        );
        let (a, b) = (bs("a"), bs("b"));
        m.try_prefetch(&a, &[], 0, 0);
        let out = m.ensure_loaded(&b).unwrap();
        match out {
            LoadOutcome::Miss { reconfig_us, .. } => assert_eq!(reconfig_us, 20),
            o => panic!("expected miss, got {o:?}"),
        }
        assert_eq!(out.stall_us(), 20);
        let s = m.stats();
        assert_eq!((s.stall_us, s.overlapped_us), (20, 0));
        assert_eq!(m.clock_us(), 20);
        assert!(!m.regions()[m.region_of(a.id).unwrap()].is_configuring());
    }

    #[test]
    fn cost_classes_rank_dispatch_cost() {
        let mut m = mgr(2);
        let (a, b, c) = (bs("a"), bs("b"), bs("c"));
        assert_eq!(m.cost_of(a.id), CostClass::FreeRegion);
        m.ensure_loaded(&a).unwrap();
        assert_eq!(m.cost_of(a.id), CostClass::Resident);
        m.ensure_loaded(&b).unwrap();
        assert_eq!(m.cost_of(c.id), CostClass::MustEvict);
        // A pending foreign transaction makes everything else IcapBusy,
        // but the transferring role itself counts as resident.
        let mut m2 = mgr(2);
        m2.try_prefetch(&a, &[], 0, 0);
        assert_eq!(m2.cost_of(a.id), CostClass::Resident);
        assert_eq!(m2.cost_of(b.id), CostClass::IcapBusy);
        assert!(CostClass::Resident < CostClass::IcapBusy, "ordering is cheapest-first");
    }
}
