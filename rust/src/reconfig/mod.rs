//! Partial-reconfiguration management — the paper's runtime core:
//! "Reconfiguration … happens every time when a kernel that is not
//! currently loaded on the FPGA is executed. In this process a LRU
//! eviction scheme is used if more roles than available regions need to be
//! handled."
//!
//! [`policy`] provides the eviction schemes (LRU as shipped in the paper,
//! plus FIFO / Random / MRU / a Belady oracle for the ablation study);
//! [`manager`] binds roles to regions, accounts hits/misses/evictions and
//! reconfiguration time; [`scheduler`] makes the whole layer anticipatory
//! — a prefetch scheduler that programs upcoming roles in the background
//! (plan horizon + demand hints) so ICAP latency overlaps compute instead
//! of stalling dispatches.

pub mod manager;
pub mod policy;
pub mod scheduler;

pub use manager::{LoadOutcome, ReconfigManager, ReconfigStats};
pub use policy::{BeladyOracle, EvictionPolicy, Fifo, Lru, Mru, PolicyKind, RandomEvict};
pub use scheduler::{CostClass, KernelHorizon, Prefetch, PrefetchPolicy, PrefetchScheduler};
