//! Predictive reconfiguration: prefetch bitstreams like a cache
//! prefetcher instead of paying the ICAP on the dispatch critical path.
//!
//! The reactive path (`ReconfigManager::ensure_loaded`) programs a PR
//! region only when a dispatch already needs it, so every miss exposes
//! the full ICAP latency to the request. But the serving stack *knows
//! the future*: a compiled [`crate::tf::plan::ExecutionPlan`] states the
//! exact upcoming kernel sequence, and the batcher publishes per-kernel
//! queue depths. This module spends that knowledge:
//!
//! * [`KernelHorizon`] — the upcoming FPGA kernel/role sequence, derived
//!   once at plan-compile time and indexed by a replay cursor.
//! * [`PrefetchScheduler`] — walks the horizon (or the demand table)
//!   ahead of the cursor and issues non-blocking
//!   [`crate::reconfig::manager::ReconfigManager::try_prefetch`] loads
//!   onto free or evictable regions, so programming overlaps compute.
//! * [`CostClass`] — the router's per-agent reconfiguration-cost probe
//!   ([`crate::fpga::device::FpgaAgent::reconfig_cost`]), letting
//!   `KernelAffinity`/`LeastLoaded` steer around agents mid-reprogram.
//!
//! **Eviction safety.** A prefetch may never displace a role the replay
//! needs *sooner* than the prefetched one, nor the role that was just
//! dispatched (its execution may still be in flight). The scheduler
//! builds that protected set from the horizon — the previous cursor
//! entry plus every window entry closer than the prefetch target — and
//! the manager additionally refuses to touch a region that is still
//! `Configuring`. Single-ICAP-port serialization is preserved: at most
//! one programming transaction is outstanding per agent, and a second
//! prefetch attempt simply reports [`Prefetch::IcapBusy`].
//!
//! Everything here is deterministic: agents are probed in slot-index
//! order, horizons are fixed at compile time, and completion is modeled
//! on the manager's virtual ICAP clock — twin sessions fed the same call
//! sequence make identical prefetch decisions (property-pinned in
//! `tests/prop_invariants.rs`).

use crate::sharding::router::Router;

/// Tuning knobs for the prefetch scheduler, carried on
/// [`crate::tf::session::SessionOptions`].
///
/// `enabled` defaults to `false`: prefetching deliberately changes the
/// miss/hit accounting that several regression tests pin, so it is an
/// explicit opt-in (`--prefetch-depth N` on the CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefetchPolicy {
    /// How many horizon entries ahead of the cursor to consider.
    pub depth: usize,
    /// Leave at least this many regions unoccupied: a prefetch that
    /// would drop the free-region count to `min_free_regions` or below
    /// must evict instead of claiming a free region (and eviction has
    /// its own safety mask). Keeps headroom for unplanned kernels.
    pub min_free_regions: usize,
    /// Master switch; when false every pump is a no-op.
    pub enabled: bool,
}

impl Default for PrefetchPolicy {
    fn default() -> Self {
        PrefetchPolicy { depth: 4, min_free_regions: 0, enabled: false }
    }
}

impl PrefetchPolicy {
    /// The default policy with prefetching off (explicit spelling).
    pub fn disabled() -> Self {
        PrefetchPolicy::default()
    }

    /// Enabled policy looking `depth` kernels ahead (clamped to >= 1).
    pub fn with_depth(depth: usize) -> Self {
        PrefetchPolicy { depth: depth.max(1), min_free_regions: 0, enabled: true }
    }
}

/// The upcoming FPGA kernel sequence of one compiled execution plan, in
/// step-emission (topological) order.
///
/// Built once by `tf::plan::compile` from the plan's FPGA dispatch
/// steps; during replay a cursor counts issued FPGA dispatches and the
/// scheduler looks at `window(cursor, depth)` — the next `depth` kernel
/// objects the replay will need. For plans with parallel branches the
/// cursor is an approximation (replay may issue independent steps in a
/// different order), which only ever makes a prefetch early or late,
/// never incorrect: correctness comes from the manager, not the horizon.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct KernelHorizon {
    entries: Vec<u64>,
}

impl KernelHorizon {
    pub fn new(entries: Vec<u64>) -> Self {
        KernelHorizon { entries }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The full kernel-object sequence.
    pub fn entries(&self) -> &[u64] {
        &self.entries
    }

    /// The next `depth` kernel objects at `cursor` (clamped to the end).
    pub fn window(&self, cursor: usize, depth: usize) -> &[u64] {
        let lo = cursor.min(self.entries.len());
        let hi = cursor.saturating_add(depth).min(self.entries.len());
        &self.entries[lo..hi]
    }
}

/// What dispatching a given role on a given agent would cost, as a
/// coarse class the router can rank without locking the world.
///
/// Returned by `FpgaAgent::reconfig_cost`; ordering is cheapest first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum CostClass {
    /// Role already resident (or its prefetch is the pending ICAP
    /// transaction on this agent): dispatch pays at most the residual
    /// programming time, usually nothing.
    Resident,
    /// Not resident, but a free region is available: dispatch pays one
    /// full reconfiguration with no eviction.
    FreeRegion,
    /// Not resident and every region is occupied: dispatch pays a full
    /// reconfiguration plus evicts someone.
    MustEvict,
    /// The agent's single ICAP port is mid-transaction for a *different*
    /// role: any reconfiguration queues behind it. Routing here while a
    /// resident replica exists elsewhere is the worst choice.
    IcapBusy,
}

impl CostClass {
    pub fn name(&self) -> &'static str {
        match self {
            CostClass::Resident => "resident",
            CostClass::FreeRegion => "free-region",
            CostClass::MustEvict => "must-evict",
            CostClass::IcapBusy => "icap-busy",
        }
    }
}

/// Outcome of one non-blocking prefetch attempt
/// (`ReconfigManager::try_prefetch` / `FpgaAgent::try_prefetch`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Prefetch {
    /// Already resident — nothing to do.
    Resident,
    /// This role's programming transaction is already in flight.
    InFlight,
    /// Another transaction occupies the single ICAP port; try later.
    IcapBusy,
    /// No free region and every eviction candidate is protected
    /// (in-flight, sooner-needed, still configuring, or reserved by
    /// `min_free_regions`).
    NoSafeRegion,
    /// The agent has no bitstream registered for this kernel object.
    UnknownKernel,
    /// Programming started in the background on `region`; it completes
    /// `reconfig_us` of virtual time later, overlapped with compute.
    Started { region: usize, reconfig_us: u64 },
}

/// Walks a [`KernelHorizon`] (or the router's demand table) and issues
/// background bitstream loads ahead of the replay cursor.
///
/// One scheduler instance serves one replay (plan path) or one pump
/// call (demand path); its only state is the policy plus issue/decline
/// counters for observability. All decisions are delegated to
/// `FpgaAgent::try_prefetch`, which owns the eviction-safety and
/// ICAP-serialization rules.
#[derive(Debug)]
pub struct PrefetchScheduler {
    policy: PrefetchPolicy,
    issued: u64,
    declined: u64,
}

impl PrefetchScheduler {
    pub fn new(policy: PrefetchPolicy) -> Self {
        PrefetchScheduler { policy, issued: 0, declined: 0 }
    }

    pub fn policy(&self) -> PrefetchPolicy {
        self.policy
    }

    /// Prefetch transactions started over this scheduler's lifetime.
    pub fn issued(&self) -> u64 {
        self.issued
    }

    /// Horizon entries that could not be prefetched anywhere (no safe
    /// region / ICAP busy on every agent).
    pub fn declined(&self) -> u64 {
        self.declined
    }

    /// Plan-cursor pump: look `depth` entries past `cursor` and start
    /// loads for any kernel not resident anywhere in the pool.
    ///
    /// The protected set for the entry at window offset `k` is the
    /// previous cursor entry (just dispatched, possibly still
    /// executing) plus window entries `0..k` (needed sooner). Agents
    /// are probed in slot-index order; the first that accepts wins.
    pub fn pump(&mut self, router: &Router, horizon: &KernelHorizon, cursor: usize) {
        if !self.policy.enabled {
            return;
        }
        let window = horizon.window(cursor, self.policy.depth);
        let mut protected: Vec<u64> = Vec::with_capacity(window.len() + 1);
        if cursor > 0 {
            protected.push(horizon.entries()[cursor - 1]);
        }
        for (off, &kernel_object) in window.iter().enumerate() {
            // Deadline hint: how many dispatches away the need is.
            let placed = self.place(router, kernel_object, &protected, off as u64);
            if !placed {
                self.declined += 1;
            }
            // Whatever happens to this entry, anything later in the
            // window must not evict it.
            protected.push(kernel_object);
        }
    }

    /// Demand pump: prefetch hot signatures first, using the batcher's
    /// queue-depth hints (`Router::hint_demand`) as the priority order.
    ///
    /// Used by the serving prewarm paths where no plan cursor exists
    /// (server startup, between batches). Every demanded kernel is
    /// protected from eviction by every other, so warming one hot
    /// signature never cannibalizes another.
    pub fn pump_demand(&mut self, router: &Router) {
        if !self.policy.enabled {
            return;
        }
        let mut demand = router.demand_snapshot();
        // Hottest first; kernel-object id breaks ties for determinism.
        demand.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let protected: Vec<u64> = demand.iter().map(|d| d.0).collect();
        for &(kernel_object, queued) in demand.iter().take(self.policy.depth.max(1)) {
            if queued == 0 {
                continue;
            }
            if !self.place(router, kernel_object, &protected, 0) {
                self.declined += 1;
            }
        }
    }

    /// Try to get `kernel_object` resident (or in flight) somewhere in
    /// the pool. Returns true if it is resident, already being
    /// programmed, or a new transaction was started.
    fn place(
        &mut self,
        router: &Router,
        kernel_object: u64,
        protected: &[u64],
        deadline_hint: u64,
    ) -> bool {
        for agent in router.agents() {
            if agent.is_resident(kernel_object) {
                return true;
            }
        }
        for agent in router.agents() {
            match agent.try_prefetch(
                kernel_object,
                protected,
                self.policy.min_free_regions,
                deadline_hint,
            ) {
                Prefetch::Started { .. } => {
                    self.issued += 1;
                    return true;
                }
                Prefetch::Resident | Prefetch::InFlight => return true,
                Prefetch::IcapBusy
                | Prefetch::NoSafeRegion
                | Prefetch::UnknownKernel => {}
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::device::{ComputeBinding, FpgaConfig};
    use crate::fpga::roles::paper_roles;
    use crate::hsa::queue::Queue;
    use crate::reconfig::policy::PolicyKind;
    use crate::sharding::pool::FpgaPool;
    use crate::sharding::router::ShardStrategy;
    use crate::tf::tensor::Tensor;
    use std::sync::Arc;

    fn mk_pool(
        agents: usize,
        regions: usize,
        roles: usize,
    ) -> (FpgaPool, Router, Vec<u64>) {
        let pool = FpgaPool::new(agents, |i| FpgaConfig {
            num_regions: regions,
            policy: PolicyKind::QueueAware.build(i as u64),
            realtime: false,
            realtime_scale: 1.0,
            trace: None,
        });
        let echo = ComputeBinding::Native(Arc::new(|ins: &[Tensor]| Ok(ins.to_vec())));
        let ids: Vec<u64> = paper_roles()
            .into_iter()
            .take(roles)
            .map(|r| pool.register_role(r, echo.clone()))
            .collect();
        let slots = pool
            .agents()
            .iter()
            .map(|a| (Arc::clone(a), Queue::new(8)))
            .collect();
        let router = Router::new(slots, ShardStrategy::KernelAffinity);
        (pool, router, ids)
    }

    #[test]
    fn horizon_window_clamps_at_the_end() {
        let h = KernelHorizon::new(vec![1, 2, 3]);
        assert_eq!(h.window(0, 2), &[1, 2]);
        assert_eq!(h.window(2, 4), &[3]);
        assert_eq!(h.window(3, 4), &[] as &[u64]);
        assert_eq!(h.window(9, 1), &[] as &[u64]);
        assert!(KernelHorizon::default().is_empty());
    }

    #[test]
    fn disabled_policy_pumps_nothing() {
        let (_pool, router, ids) = mk_pool(1, 2, 2);
        let horizon = KernelHorizon::new(vec![ids[0], ids[1]]);
        let mut sched = PrefetchScheduler::new(PrefetchPolicy::disabled());
        sched.pump(&router, &horizon, 0);
        assert_eq!(sched.issued(), 0);
        assert_eq!(router.agent(0).reconfig_stats().prefetches, 0);
    }

    #[test]
    fn pump_loads_upcoming_roles_onto_free_regions() {
        let (_pool, router, ids) = mk_pool(1, 2, 2);
        let horizon = KernelHorizon::new(vec![ids[0], ids[1]]);
        let mut sched = PrefetchScheduler::new(PrefetchPolicy::with_depth(2));
        sched.pump(&router, &horizon, 0);
        // Single ICAP port: only the first window entry starts.
        assert_eq!(sched.issued(), 1);
        assert!(router.agent(0).is_resident(ids[0]));
        assert!(!router.agent(0).is_resident(ids[1]));
        let stats = router.agent(0).reconfig_stats();
        assert_eq!(stats.prefetches, 1);
        assert_eq!(stats.misses, 0, "prefetch is not a dispatch miss");
    }

    #[test]
    fn pump_never_evicts_sooner_needed_roles() {
        let (_pool, router, ids) = mk_pool(1, 1, 2);
        let horizon = KernelHorizon::new(vec![ids[0], ids[1]]);
        let mut sched = PrefetchScheduler::new(PrefetchPolicy::with_depth(2));
        // Cursor 0: window is [ids0, ids1]. ids0 claims the only
        // region; ids1 must NOT evict it (sooner-needed).
        sched.pump(&router, &horizon, 0);
        assert_eq!(sched.issued(), 1);
        assert!(router.agent(0).is_resident(ids[0]));
        assert_eq!(sched.declined(), 1, "ids1 had no safe region");
    }

    #[test]
    fn pump_spills_to_the_next_agent_when_first_is_busy() {
        let (_pool, router, ids) = mk_pool(2, 1, 2);
        let horizon = KernelHorizon::new(vec![ids[0], ids[1]]);
        let mut sched = PrefetchScheduler::new(PrefetchPolicy::with_depth(2));
        sched.pump(&router, &horizon, 0);
        // Agent 0's ICAP takes ids0; ids1 lands on agent 1.
        assert_eq!(sched.issued(), 2);
        assert!(router.agent(0).is_resident(ids[0]));
        assert!(router.agent(1).is_resident(ids[1]));
    }

    #[test]
    fn demand_pump_warms_hottest_signature_first() {
        let (_pool, router, ids) = mk_pool(1, 1, 2);
        router.hint_demand(ids[0], 1);
        router.hint_demand(ids[1], 9);
        let mut sched = PrefetchScheduler::new(PrefetchPolicy::with_depth(4));
        sched.pump_demand(&router);
        // One region, one ICAP: only the hottest kernel fits.
        assert!(router.agent(0).is_resident(ids[1]));
        assert!(!router.agent(0).is_resident(ids[0]));
        assert_eq!(sched.issued(), 1);
    }

    #[test]
    fn twin_schedulers_make_identical_decisions() {
        let mk = || {
            let (pool, router, ids) = mk_pool(2, 2, 4);
            let horizon =
                KernelHorizon::new(vec![ids[0], ids[1], ids[2], ids[3], ids[0]]);
            (pool, router, horizon)
        };
        let (_p1, r1, h1) = mk();
        let (_p2, r2, h2) = mk();
        let mut s1 = PrefetchScheduler::new(PrefetchPolicy::with_depth(3));
        let mut s2 = PrefetchScheduler::new(PrefetchPolicy::with_depth(3));
        for cursor in 0..h1.len() {
            s1.pump(&r1, &h1, cursor);
            s2.pump(&r2, &h2, cursor);
        }
        assert_eq!(s1.issued(), s2.issued());
        assert_eq!(s1.declined(), s2.declined());
        for i in 0..r1.len() {
            assert_eq!(
                r1.agent(i).reconfig_stats(),
                r2.agent(i).reconfig_stats(),
                "agent {i} diverged"
            );
        }
    }
}
