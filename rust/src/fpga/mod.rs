//! FPGA substrate: a simulator of the paper's Ultra96 programmable logic.
//!
//! The paper's testbed is a Zynq UltraScale+ ZU3EG whose PL carries a
//! static *shell* plus partially-reconfigurable *regions*; pre-synthesized
//! *role* bitstreams are loaded into regions at dispatch time. This module
//! models exactly those pieces:
//!
//! * [`resources`] — LUT/FF/BRAM/DSP vectors and the ZU3EG inventory
//!   (Table I's denominators);
//! * [`datapath`] — per-role cycle models (Table III's numerators);
//! * [`synthesis`] — a resource estimator over datapath descriptions
//!   (regenerates Table I);
//! * [`bitstream`] / [`region`] / [`shell`] — partial-reconfiguration
//!   objects; [`icap`] — the PCAP/ICAP configuration-port timing model
//!   (Table II's reconfiguration row);
//! * [`roles`] — the paper's four roles as built-in bitstreams;
//! * [`device`] — [`device::FpgaAgent`], the HSA agent wired to all of the
//!   above, with numerics delegated to PJRT artifacts or native kernels.

pub mod bitstream;
pub mod datapath;
pub mod device;
pub mod hls;
pub mod icap;
pub mod region;
pub mod resources;
pub mod roles;
pub mod shell;
pub mod synthesis;

pub use bitstream::Bitstream;
pub use datapath::{DatapathSpec, RoleOp};
pub use device::{ComputeBinding, FpgaAgent, FpgaConfig};
pub use icap::Icap;
pub use region::{PrRegion, RegionState};
pub use resources::{ResourceVector, ZU3EG};
pub use shell::Shell;
