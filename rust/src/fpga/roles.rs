//! The paper's four roles (§IV) as built-in pre-synthesized bitstreams,
//! plus the shell netlist and extra roles used by the examples.
//!
//! Component lists and datapath parameters are calibrated against Table I
//! (resources) and Table III (OP/cycle) — see DESIGN.md §6 for the
//! derivations. Role 1's FF/BRAM/DSP columns are garbled in the published
//! table; ours are estimates from the role-2 structure (double buffer
//! instead of barrier logic) and are labeled `(est.)` in bench output.

use crate::fpga::bitstream::Bitstream;
use crate::fpga::datapath::{DatapathSpec, RoleOp};
use crate::fpga::resources::ResourceVector;
use crate::fpga::synthesis::{estimate, Component};

/// PL clock all roles close timing at (conservative for ZU3EG speedgrade-1).
pub const PL_CLOCK_MHZ: u32 = 150;

/// Partial bitstream size for one PR region of the Ultra96 floorplan
/// (~quarter-device partition). Chosen so the default PCAP model lands on
/// the paper's 7424 µs reconfiguration time: 7424 µs ≈ 350 µs setup +
/// bytes / 134.22 B/µs  =>  bytes ≈ 949 639 ≈ 928 KiB.
pub const ROLE_BITSTREAM_BYTES: u64 = 949_632;

fn fc_nominal() -> RoleOp {
    RoleOp::FcF32 { m: 64, k: 64, n: 64 }
}

fn conv5_nominal() -> RoleOp {
    RoleOp::ConvI16 { cin: 1, h: 28, w: 28, kh: 5, kw: 5, filters: 1 }
}

fn conv3_nominal() -> RoleOp {
    RoleOp::ConvI16 { cin: 1, h: 28, w: 28, kh: 3, kw: 3, filters: 2 }
}

/// Shell netlist (static logic: interconnect, 2 DMA engines, PCAP/PR
/// controller, queue-doorbell MMIO block).
pub fn shell_components() -> Vec<Component> {
    vec![
        Component::AxiInterconnect,
        Component::DmaEngine,
        Component::DmaEngine,
        Component::PcapController,
        Component::DoorbellMmio,
    ]
}

/// Shell synthesis estimate (Table I row 1: 9915 LUT / 8544 FF / 10 BRAM).
pub fn shell_resources() -> ResourceVector {
    estimate(&shell_components())
}

/// Role 1 — fully connected, float32 (4 f32 MACs, double-buffered output
/// for barrier-free full pipelining).
pub fn role1_components() -> Vec<Component> {
    vec![
        Component::ControlFsm,
        Component::AxiStreamIf,
        Component::AxiStreamIf,
        Component::F32Mac,
        Component::F32Mac,
        Component::F32Mac,
        Component::F32Mac,
        Component::DoubleBuffer,
        Component::WeightBuffer { kb: 32 },
        Component::StreamFifo { kb: 20 },
        Component::StreamFifo { kb: 20 },
    ]
}

pub fn role1_spec() -> DatapathSpec {
    DatapathSpec {
        name: "role1_fc",
        op: fc_nominal(),
        macs_per_cycle: 4,
        ii: 1,
        pipeline_depth: 32,
        burst_bytes: 4096,
        burst_overhead_cycles: 8,
        barriers_per_pass: 0,
        barrier_stall_cycles: 0,
        clock_mhz: PL_CLOCK_MHZ,
    }
}

/// Role 2 — fully connected with barrier, float32 (same MAC array; the
/// barrier serializes accumulate/writeback so the double buffer is
/// replaced by synchronization logic).
pub fn role2_components() -> Vec<Component> {
    vec![
        Component::ControlFsm,
        Component::AxiStreamIf,
        Component::AxiStreamIf,
        Component::F32Mac,
        Component::F32Mac,
        Component::F32Mac,
        Component::F32Mac,
        Component::BarrierSync,
        Component::WeightBuffer { kb: 32 },
        Component::StreamFifo { kb: 20 },
        Component::StreamFifo { kb: 20 },
    ]
}

pub fn role2_spec() -> DatapathSpec {
    DatapathSpec {
        name: "role2_fc_barrier",
        op: fc_nominal(),
        macs_per_cycle: 4,
        ii: 1,
        pipeline_depth: 32,
        burst_bytes: 4096,
        burst_overhead_cycles: 8,
        // One barrier per output row: the PE partial sums must all arrive
        // before the row is committed (paper: "fully connected with
        // barrier"). Stall = pipeline drain + handshake, calibrated to the
        // Table III 3.03x ratio.
        barriers_per_pass: 64,
        barrier_stall_cycles: 1178,
        clock_mhz: PL_CLOCK_MHZ,
    }
}

/// Role 3 — conv 5×5, 1 filter, fixed weights, int16. 25 constant taps:
/// CSD-cheap ones become LUT shift/add chains, hard ones keep DSP48s
/// (6 DSPs, matching Table I).
pub fn role3_components() -> Vec<Component> {
    let mut c = vec![
        Component::ControlFsm,
        Component::AxiStreamIf,
        Component::AxiStreamIf,
        Component::LineBuffer { rows: 4 },
        Component::QuantSat,
        Component::StreamFifo { kb: 25 },
        Component::StreamFifo { kb: 25 },
    ];
    // 25 taps: 19 LUT-mapped + 6 DSP-mapped.
    for _ in 0..19 {
        c.push(Component::I16TapLut);
    }
    for _ in 0..6 {
        c.push(Component::I16TapDsp);
    }
    // 24-node accumulation tree.
    for _ in 0..24 {
        c.push(Component::AdderTreeNode);
    }
    c
}

pub fn role3_spec() -> DatapathSpec {
    DatapathSpec {
        name: "role3_conv5x5",
        op: conv5_nominal(),
        macs_per_cycle: 25, // all taps fire each cycle (line-buffered window)
        ii: 1,
        pipeline_depth: 40,
        burst_bytes: 4096,
        burst_overhead_cycles: 8,
        barriers_per_pass: 0,
        barrier_stall_cycles: 0,
        clock_mhz: PL_CLOCK_MHZ,
    }
}

/// Role 4 — conv 3×3, 2 filters, fixed weights, int16. 18 taps (12 DSP,
/// 6 LUT) + two filter pipelines + a 2-way output mux.
pub fn role4_components() -> Vec<Component> {
    let mut c = vec![
        Component::ControlFsm,
        Component::AxiStreamIf,
        Component::AxiStreamIf,
        Component::LineBuffer { rows: 2 },
        Component::QuantSat,
        Component::QuantSat,
        Component::FilterPipeline,
        Component::FilterPipeline,
        Component::OutputMux { ways: 2 },
        Component::StreamFifo { kb: 29 },
        Component::StreamFifo { kb: 29 },
    ];
    for _ in 0..6 {
        c.push(Component::I16TapLut);
    }
    for _ in 0..12 {
        c.push(Component::I16TapDsp);
    }
    for _ in 0..16 {
        c.push(Component::AdderTreeNode);
    }
    c
}

pub fn role4_spec() -> DatapathSpec {
    DatapathSpec {
        name: "role4_conv3x3",
        op: conv3_nominal(),
        macs_per_cycle: 18, // 2 filters x 9 taps in parallel
        ii: 1,
        pipeline_depth: 28,
        burst_bytes: 4096,
        burst_overhead_cycles: 8,
        barriers_per_pass: 0,
        barrier_stall_cycles: 0,
        clock_mhz: PL_CLOCK_MHZ,
    }
}

/// Build the four paper bitstreams (ids are fresh per call).
pub fn paper_roles() -> Vec<Bitstream> {
    vec![
        Bitstream::new(
            "role1_fc",
            ROLE_BITSTREAM_BYTES,
            estimate(&role1_components()),
            role1_spec(),
        ),
        Bitstream::new(
            "role2_fc_barrier",
            ROLE_BITSTREAM_BYTES,
            estimate(&role2_components()),
            role2_spec(),
        ),
        Bitstream::new(
            "role3_conv5x5",
            ROLE_BITSTREAM_BYTES,
            estimate(&role3_components()),
            role3_spec(),
        ),
        Bitstream::new(
            "role4_conv3x3",
            ROLE_BITSTREAM_BYTES,
            estimate(&role4_components()),
            role4_spec(),
        ),
    ]
}

/// ReLU-fused variants of the four paper roles, for the plan compiler's
/// op-fusion pass (`tf::fusion`): the same streaming datapaths with one
/// extra saturation/clamp unit on the output stream, so `op+relu` executes
/// as a single dispatch in a single PR region. Timing is unchanged — a
/// pipelined clamp costs resources, not cycles — which is exactly why
/// fusion pays: one dispatch and one resident role instead of a conv role
/// *plus* a CPU relu hop.
pub fn fused_paper_roles() -> Vec<Bitstream> {
    let variants: Vec<(&'static str, DatapathSpec, Vec<Component>)> = vec![
        ("role1_fc_relu", role1_spec(), role1_components()),
        ("role2_fc_barrier_relu", role2_spec(), role2_components()),
        ("role3_conv5x5_relu", role3_spec(), role3_components()),
        ("role4_conv3x3_relu", role4_spec(), role4_components()),
    ];
    variants
        .into_iter()
        .map(|(name, mut spec, mut comps)| {
            spec.name = name;
            comps.push(Component::QuantSat); // the output clamp stage
            Bitstream::new(name, ROLE_BITSTREAM_BYTES, estimate(&comps), spec)
        })
        .collect()
}

/// An extra "preprocessing" role for the multi-tenant example (the paper's
/// pre/post-processing sharing story): a generic streaming op.
pub fn preprocess_role() -> Bitstream {
    let spec = DatapathSpec {
        name: "preprocess_stream",
        op: RoleOp::Stream { elements: 784, ops_per_element: 8 },
        macs_per_cycle: 4,
        ii: 1,
        pipeline_depth: 16,
        burst_bytes: 4096,
        burst_overhead_cycles: 8,
        barriers_per_pass: 0,
        barrier_stall_cycles: 0,
        clock_mhz: PL_CLOCK_MHZ,
    };
    let comps = vec![
        Component::ControlFsm,
        Component::AxiStreamIf,
        Component::AxiStreamIf,
        Component::QuantSat,
        Component::StreamFifo { kb: 16 },
        Component::StreamFifo { kb: 16 },
    ];
    Bitstream::new("preprocess_stream", ROLE_BITSTREAM_BYTES, estimate(&comps), spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table I, shell row: 9915 (14.1%) | 8544 (6.1%) | 10 (4.6%) | 0.
    #[test]
    fn shell_matches_table1() {
        let r = shell_resources();
        assert_eq!(r, ResourceVector::new(9915, 8544, 10, 0));
    }

    /// Table I, role 1 row: LUTs published as 9984 (14.1%); other columns
    /// estimated.
    #[test]
    fn role1_luts_match_table1() {
        let r = estimate(&role1_components());
        assert_eq!(r.luts, 9984);
        assert_eq!(r.dsps, 8, "4 f32 MACs x 2 DSP48E2");
    }

    /// Table I, role 2 row: 9501 | 7851 | 23 | 8.
    #[test]
    fn role2_matches_table1() {
        let r = estimate(&role2_components());
        assert_eq!(r, ResourceVector::new(9501, 7851, 23, 8));
    }

    /// Table I, role 3 row: 5091 | 4935 | 21 | 6.
    #[test]
    fn role3_matches_table1() {
        let r = estimate(&role3_components());
        assert_eq!(r, ResourceVector::new(5091, 4935, 21, 6));
    }

    /// Table I, role 4 row: 7881 | 7926 | 21 | 12. The LUT column is ±1 of
    /// the paper (no integer component decomposition hits 7881 exactly
    /// given the shared components' parities); the printed percentage
    /// (11.2 %) is identical.
    #[test]
    fn role4_matches_table1() {
        let r = estimate(&role4_components());
        assert!((r.luts as i64 - 7881).abs() <= 1, "role4 LUTs {}", r.luts);
        assert_eq!(r.ffs, 7926);
        assert_eq!(r.bram36, 21);
        assert_eq!(r.dsps, 12);
        let pct = r.utilization_pct(&crate::fpga::resources::ZU3EG);
        assert!((pct[0] - 11.2).abs() < 0.05, "LUT% {}", pct[0]);
    }

    #[test]
    fn reconfig_time_matches_table2() {
        let icap = crate::fpga::icap::Icap::default();
        let us = icap.reconfig_time_us(ROLE_BITSTREAM_BYTES);
        // Paper: 7424 µs.
        assert!((us as i64 - 7424).abs() < 100, "reconfig {us} µs");
    }

    #[test]
    fn all_roles_have_distinct_ids_and_names() {
        let roles = paper_roles();
        let mut names: Vec<&str> = roles.iter().map(|r| r.name.as_str()).collect();
        names.dedup();
        assert_eq!(names.len(), 4);
        let mut ids: Vec<u64> = roles.iter().map(|r| r.id.0).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 4);
    }

    #[test]
    fn role_ops_per_cycle_land_on_table3_numerators() {
        // FPGA-side achieved OP/cycle; Table III ratios divide by the A53
        // model (see cpu::a53 tests for the end-to-end ratio check).
        let r1 = role1_spec();
        let opc1 = r1.ops_per_cycle(&r1.op);
        assert!((opc1 - 7.99).abs() < 0.05, "role1 {opc1}");
        let r2 = role2_spec();
        let opc2 = r2.ops_per_cycle(&r2.op);
        assert!((opc2 - 3.72).abs() < 0.05, "role2 {opc2}");
        let r3 = role3_spec();
        let opc3 = r3.ops_per_cycle(&r3.op);
        assert!((opc3 - 46.2).abs() < 0.5, "role3 {opc3}");
        let r4 = role4_spec();
        let opc4 = r4.ops_per_cycle(&r4.op);
        assert!((opc4 - 33.8).abs() < 0.5, "role4 {opc4}");
    }

    #[test]
    fn roles_fit_in_a_quarter_device_region() {
        let cap = ResourceVector::new(
            crate::fpga::resources::ZU3EG.luts / 4,
            crate::fpga::resources::ZU3EG.ffs / 4,
            crate::fpga::resources::ZU3EG.bram36 / 4,
            crate::fpga::resources::ZU3EG.dsps / 4,
        );
        for r in paper_roles().into_iter().chain(fused_paper_roles()) {
            assert!(r.resources.fits_in(&cap), "{} does not fit: {}", r.name, r.resources);
        }
    }

    #[test]
    fn fused_roles_distinct_and_cost_only_a_clamp_stage() {
        let base = paper_roles();
        let fused = fused_paper_roles();
        assert_eq!(fused.len(), base.len());
        let clamp = Component::QuantSat.cost();
        for (b, f) in base.iter().zip(&fused) {
            assert_ne!(b.id, f.id);
            assert!(f.name.ends_with("_relu"), "{}", f.name);
            assert_eq!(f.resources, b.resources + clamp, "{}", f.name);
            // Same cycle model: fusion saves a dispatch, not datapath time.
            assert_eq!(f.spec.ops_per_cycle(&f.spec.op), b.spec.ops_per_cycle(&b.spec.op));
        }
    }
}
