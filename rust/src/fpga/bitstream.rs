//! Pre-synthesized partial bitstreams ("roles").
//!
//! In the paper, a TF kernel registered for the FPGA device *is* a
//! pre-synthesized bitstream. Our bitstream object carries everything its
//! binary counterpart determines: identity, byte size (reconfiguration
//! cost), resource usage (Table I row), and the datapath spec (timing).

use crate::fpga::datapath::DatapathSpec;
use crate::fpga::resources::ResourceVector;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Unique role/bitstream identity (the `kernel_object` of dispatch packets
/// targeting the FPGA agent).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RoleId(pub u64);

static NEXT_ROLE_ID: AtomicU64 = AtomicU64::new(1);

impl RoleId {
    pub fn fresh() -> RoleId {
        RoleId(NEXT_ROLE_ID.fetch_add(1, Ordering::Relaxed))
    }
}

/// A pre-synthesized role bitstream.
#[derive(Debug, Clone)]
pub struct Bitstream {
    pub id: RoleId,
    pub name: String,
    /// Partial bitstream size in bytes (drives reconfiguration latency).
    pub bytes: u64,
    /// Synthesis result (one Table I row).
    pub resources: ResourceVector,
    /// Timing/structure model of the synthesized datapath.
    pub spec: Arc<DatapathSpec>,
}

impl Bitstream {
    pub fn new(
        name: impl Into<String>,
        bytes: u64,
        resources: ResourceVector,
        spec: DatapathSpec,
    ) -> Bitstream {
        Bitstream {
            id: RoleId::fresh(),
            name: name.into(),
            bytes,
            resources,
            spec: Arc::new(spec),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::datapath::RoleOp;

    fn spec() -> DatapathSpec {
        DatapathSpec {
            name: "t",
            op: RoleOp::Stream { elements: 1, ops_per_element: 2 },
            macs_per_cycle: 1,
            ii: 1,
            pipeline_depth: 1,
            burst_bytes: 64,
            burst_overhead_cycles: 1,
            barriers_per_pass: 0,
            barrier_stall_cycles: 0,
            clock_mhz: 100,
        }
    }

    #[test]
    fn role_ids_are_unique() {
        let a = Bitstream::new("a", 1, ResourceVector::ZERO, spec());
        let b = Bitstream::new("b", 1, ResourceVector::ZERO, spec());
        assert_ne!(a.id, b.id);
    }

    #[test]
    fn fresh_ids_monotonic() {
        let a = RoleId::fresh();
        let b = RoleId::fresh();
        assert!(b.0 > a.0);
    }
}
