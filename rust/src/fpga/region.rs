//! Partial-reconfiguration regions.

use crate::fpga::bitstream::RoleId;
use crate::fpga::resources::ResourceVector;

/// Lifecycle of a PR region.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RegionState {
    /// Nothing loaded since power-up (grey box).
    Empty,
    /// PCAP transfer in progress.
    Configuring,
    /// A role is resident and idle.
    Ready,
    /// A role is resident and executing a dispatch.
    Busy,
}

/// One reconfigurable partition of the shell floorplan.
#[derive(Debug, Clone)]
pub struct PrRegion {
    pub id: usize,
    /// Resources the floorplan grants this partition (an incoming role must
    /// fit; the shell validates on load).
    pub capacity: ResourceVector,
    pub state: RegionState,
    /// Resident role, if any.
    pub loaded: Option<RoleId>,
    /// Monotonic ticks for replacement policies.
    pub loaded_at_tick: u64,
    pub last_used_tick: u64,
    /// Lifetime counters.
    pub loads: u64,
    pub dispatches: u64,
}

impl PrRegion {
    pub fn new(id: usize, capacity: ResourceVector) -> PrRegion {
        PrRegion {
            id,
            capacity,
            state: RegionState::Empty,
            loaded: None,
            loaded_at_tick: 0,
            last_used_tick: 0,
            loads: 0,
            dispatches: 0,
        }
    }

    pub fn is_free(&self) -> bool {
        self.loaded.is_none()
    }

    /// PCAP transfer still in flight (set by the reconfiguration
    /// manager for background prefetches; such a region must never be
    /// chosen as an eviction victim until the transfer settles).
    pub fn is_configuring(&self) -> bool {
        self.state == RegionState::Configuring
    }

    /// Install a role (the shell has already modeled the PCAP time).
    pub fn load(&mut self, role: RoleId, tick: u64) {
        self.loaded = Some(role);
        self.state = RegionState::Ready;
        self.loaded_at_tick = tick;
        self.last_used_tick = tick;
        self.loads += 1;
    }

    pub fn evict(&mut self) -> Option<RoleId> {
        self.state = RegionState::Empty;
        self.loaded.take()
    }

    pub fn touch(&mut self, tick: u64) {
        self.last_used_tick = tick;
        self.dispatches += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let mut r = PrRegion::new(0, ResourceVector::new(100, 100, 10, 10));
        assert!(r.is_free());
        assert_eq!(r.state, RegionState::Empty);
        let role = RoleId(7);
        r.load(role, 5);
        assert_eq!(r.loaded, Some(role));
        assert_eq!(r.state, RegionState::Ready);
        assert_eq!(r.loaded_at_tick, 5);
        r.touch(9);
        assert_eq!(r.last_used_tick, 9);
        assert_eq!(r.dispatches, 1);
        assert_eq!(r.evict(), Some(role));
        assert!(r.is_free());
    }

    #[test]
    fn counters_accumulate() {
        let mut r = PrRegion::new(0, ResourceVector::ZERO);
        r.load(RoleId(1), 0);
        r.evict();
        r.load(RoleId(2), 1);
        assert_eq!(r.loads, 2);
    }
}
