//! PCAP/ICAP configuration-port timing — Table II's "reconfiguration" row.
//!
//! Partial reconfiguration streams a bitstream through the processor
//! configuration access port. Time is `bytes / bandwidth` plus a fixed
//! driver setup cost. With the Ultra96's PCAP sustaining ~128 MB/s and a
//! quarter-device PR region bitstream of ~950 KB, reconfiguration lands at
//! the paper's measured 7.4 ms.

use crate::fpga::bitstream::RoleId;
use std::sync::atomic::{AtomicU64, Ordering};

/// One in-flight programming transaction on the single configuration
/// port.
///
/// The real PCAP serializes transfers, so the reconfiguration manager
/// holds at most one of these at a time per agent. Completion is modeled
/// against the manager's virtual clock: the transfer is done once the
/// clock reaches `ready_at_us`. Dispatches on *other* regions proceed
/// while the transaction is pending — that overlap is exactly what the
/// prefetch scheduler buys (`reconfig::scheduler`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IcapTransaction {
    /// Role being streamed in.
    pub role: RoleId,
    /// Destination PR region.
    pub region: usize,
    /// Modeled transfer duration (setup + bytes/bandwidth).
    pub reconfig_us: u64,
    /// Virtual-clock timestamp at which the region becomes `Ready`.
    pub ready_at_us: u64,
    /// Scheduler's deadline hint: how many dispatches away the need is
    /// (0 = needed immediately). Observability only.
    pub deadline_hint: u64,
}

impl IcapTransaction {
    /// Remaining transfer time at virtual time `now_us` (0 if done).
    pub fn remaining_us(&self, now_us: u64) -> u64 {
        self.ready_at_us.saturating_sub(now_us)
    }
}

/// Configuration port model. One reconfiguration at a time (the real PCAP
/// serializes too) — callers hold the shell lock across `reconfigure`.
#[derive(Debug)]
pub struct Icap {
    /// Sustained throughput in bytes per microsecond (128 MB/s = 128 B/µs...
    /// careful: 128 MB/s = 134.217728 B/µs; we use binary MB).
    bytes_per_us: f64,
    /// Fixed per-reconfiguration driver/DMA setup cost.
    setup_us: u64,
    total_reconfigs: AtomicU64,
    total_us: AtomicU64,
}

/// Default sustained PCAP bandwidth (bytes/µs). 128 MiB/s ≈ 134.22 B/µs.
pub const DEFAULT_PCAP_BYTES_PER_US: f64 = 128.0 * 1024.0 * 1024.0 / 1_000_000.0;

/// Fixed driver overhead per reconfiguration (device-tree overlay + DMA
/// descriptor setup on the Ultra96's fpga_manager path).
pub const DEFAULT_SETUP_US: u64 = 350;

impl Default for Icap {
    fn default() -> Self {
        Icap::new(DEFAULT_PCAP_BYTES_PER_US, DEFAULT_SETUP_US)
    }
}

impl Icap {
    pub fn new(bytes_per_us: f64, setup_us: u64) -> Icap {
        assert!(bytes_per_us > 0.0);
        Icap {
            bytes_per_us,
            setup_us,
            total_reconfigs: AtomicU64::new(0),
            total_us: AtomicU64::new(0),
        }
    }

    /// Microseconds to load a bitstream of `bytes`.
    pub fn reconfig_time_us(&self, bytes: u64) -> u64 {
        self.setup_us + (bytes as f64 / self.bytes_per_us).round() as u64
    }

    /// Account one reconfiguration; returns its modeled duration in µs.
    pub fn reconfigure(&self, bytes: u64) -> u64 {
        let us = self.reconfig_time_us(bytes);
        self.total_reconfigs.fetch_add(1, Ordering::Relaxed);
        self.total_us.fetch_add(us, Ordering::Relaxed);
        us
    }

    pub fn total_reconfigs(&self) -> u64 {
        self.total_reconfigs.load(Ordering::Relaxed)
    }

    pub fn total_us(&self) -> u64 {
        self.total_us.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_reconfig_time_shape() {
        // The role bitstream size is chosen in roles.rs such that the
        // default ICAP lands near the paper's 7424 µs.
        let icap = Icap::default();
        let us = icap.reconfig_time_us(crate::fpga::roles::ROLE_BITSTREAM_BYTES);
        assert!(
            (7000..8000).contains(&us),
            "reconfig {us} µs not in the paper's ballpark"
        );
    }

    #[test]
    fn time_scales_linearly_with_bytes() {
        let icap = Icap::new(100.0, 0);
        assert_eq!(icap.reconfig_time_us(1000), 10);
        assert_eq!(icap.reconfig_time_us(2000), 20);
    }

    #[test]
    fn setup_cost_added() {
        let icap = Icap::new(100.0, 42);
        assert_eq!(icap.reconfig_time_us(0), 42);
    }

    #[test]
    fn transaction_remaining_counts_down_and_clamps() {
        let txn = IcapTransaction {
            role: RoleId(1),
            region: 0,
            reconfig_us: 100,
            ready_at_us: 250,
            deadline_hint: 2,
        };
        assert_eq!(txn.remaining_us(150), 100);
        assert_eq!(txn.remaining_us(249), 1);
        assert_eq!(txn.remaining_us(250), 0);
        assert_eq!(txn.remaining_us(9000), 0, "never underflows");
    }

    #[test]
    fn accounting_accumulates() {
        let icap = Icap::new(1000.0, 0);
        icap.reconfigure(5000);
        icap.reconfigure(5000);
        assert_eq!(icap.total_reconfigs(), 2);
        assert_eq!(icap.total_us(), 10);
    }
}
