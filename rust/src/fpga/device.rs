//! The FPGA HSA agent: dispatch → residency check → (partial
//! reconfiguration) → datapath execution.
//!
//! This is where the paper's pieces meet: a kernel-dispatch packet names a
//! pre-synthesized bitstream (the registered TF kernel); the reconfiguration
//! manager ensures it is resident (LRU-evicting if the working set exceeds
//! the PR regions); numerics run through the role's *compute binding* —
//! the AOT-compiled PJRT artifact (the functional stand-in for the real
//! datapath) or a native kernel — while timing comes from the datapath
//! cycle model and the ICAP transfer model.

use crate::fpga::bitstream::Bitstream;
use crate::fpga::shell::Shell;
use crate::hsa::agent::{Agent, AgentInfo, DeviceType};
use crate::hsa::error::{HsaError, Result};
use crate::hsa::packet::KernelDispatchPacket;
use crate::fpga::bitstream::RoleId;
use crate::reconfig::manager::{LoadOutcome, ReconfigManager, ReconfigStats};
use crate::reconfig::policy::EvictionPolicy;
use crate::reconfig::scheduler::{CostClass, Prefetch};
use crate::runtime::pjrt::PjrtHandle;
use crate::tf::tensor::Tensor;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// A deterministic fault-injection schedule for one agent (test-only
/// machinery, but compiled in: the chaos suite drives a release-built
/// server with it). Each dispatch draws a fault decision from a PRNG
/// seeded with `seed ^ hash(dispatch_index)`, so a given `(plan, index)`
/// pair always yields the same fault — chaos runs replay bit-identically
/// for a fixed seed, independent of thread interleaving.
///
/// Probabilities are evaluated in order drop → stall → slow against one
/// uniform draw, so their sum should stay ≤ 1.0.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub seed: u64,
    /// Probability a dispatch fails immediately with an agent-down error.
    pub drop_prob: f64,
    /// Probability a dispatch stalls (sleeps `stall`) *before* doing any
    /// work — the wedged-agent case health probes must catch.
    pub stall_prob: f64,
    pub stall: Duration,
    /// Probability a dispatch completes correctly but `slow` late.
    pub slow_prob: f64,
    pub slow: Duration,
}

impl FaultPlan {
    /// A plan that never fires (handy as a mutation base).
    pub fn none(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            drop_prob: 0.0,
            stall_prob: 0.0,
            stall: Duration::ZERO,
            slow_prob: 0.0,
            slow: Duration::ZERO,
        }
    }

    fn decide(&self, index: u64) -> Option<Fault> {
        let mut rng = crate::util::prng::Rng::new(
            self.seed ^ index.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let u = rng.f64();
        if u < self.drop_prob {
            return Some(Fault::Drop);
        }
        if u < self.drop_prob + self.stall_prob {
            return Some(Fault::Stall(self.stall));
        }
        if u < self.drop_prob + self.stall_prob + self.slow_prob {
            return Some(Fault::Slow(self.slow));
        }
        None
    }
}

#[derive(Debug, Clone, Copy)]
enum Fault {
    Drop,
    Stall(Duration),
    Slow(Duration),
}

/// Point-in-time health of one agent, as seen by the router's probe.
#[derive(Debug, Clone)]
pub struct AgentHealth {
    /// False after [`FpgaAgent::kill`] (until revived).
    pub alive: bool,
    /// Executions currently inside `execute`.
    pub executing: u64,
    /// Time since the last completed execution (None = never completed).
    pub heartbeat_age: Option<Duration>,
    /// Age of the oldest execution still inside `execute` (None = idle).
    /// A wedged agent shows this growing without bound.
    pub oldest_inflight_age: Option<Duration>,
}

/// How a role's numerics are computed when it executes.
#[derive(Clone)]
pub enum ComputeBinding {
    /// Execute the AOT-compiled HLO module of this kernel via PJRT — the
    /// functional model of the synthesized bitstream (the default).
    Pjrt { handle: PjrtHandle, module: String },
    /// Native Rust kernel (used by substrate tests and the extra roles that
    /// have no Python counterpart).
    Native(Arc<dyn Fn(&[Tensor]) -> Result<Vec<Tensor>> + Send + Sync>),
    /// PJRT when the dispatch matches the artifact's (shape-locked)
    /// signature — real bitstreams are shape-locked too — otherwise the
    /// generic native datapath.
    PjrtOrNative {
        handle: PjrtHandle,
        module: String,
        signature: Vec<crate::runtime::artifact::TensorMeta>,
        native: Arc<dyn Fn(&[Tensor]) -> Result<Vec<Tensor>> + Send + Sync>,
    },
}

impl ComputeBinding {
    fn signature_matches(
        signature: &[crate::runtime::artifact::TensorMeta],
        inputs: &[Tensor],
    ) -> bool {
        signature.len() == inputs.len()
            && signature
                .iter()
                .zip(inputs)
                .all(|(m, t)| m.shape == t.shape() && m.dtype == t.dtype())
    }
}

struct FpgaRole {
    bitstream: Bitstream,
    binding: ComputeBinding,
    dispatches: AtomicU64,
}

/// Configuration of the simulated FPGA.
pub struct FpgaConfig {
    pub num_regions: usize,
    pub policy: Box<dyn EvictionPolicy>,
    /// If true, modeled durations (reconfig, datapath) are also slept in
    /// wall-clock (scaled by `realtime_scale`) so end-to-end latencies feel
    /// like the device. Benches keep this off and read virtual time.
    pub realtime: bool,
    pub realtime_scale: f64,
    /// Optional event trace (reconfigurations + kernel executions land on
    /// the "fpga" track, lane = PR region).
    pub trace: Option<crate::trace::recorder::TraceRecorder>,
}

impl Default for FpgaConfig {
    fn default() -> Self {
        FpgaConfig {
            num_regions: 2,
            policy: Box::new(crate::reconfig::policy::Lru),
            realtime: false,
            realtime_scale: 1.0,
            trace: None,
        }
    }
}

/// The simulated Ultra96 FPGA agent.
pub struct FpgaAgent {
    info: AgentInfo,
    manager: Mutex<ReconfigManager>,
    roles: RwLock<HashMap<u64, Arc<FpgaRole>>>,
    virtual_ns: AtomicU64,
    realtime: bool,
    realtime_scale: f64,
    trace: Option<crate::trace::recorder::TraceRecorder>,
    // --- fault injection + health (see FaultPlan / AgentHealth) ---
    /// True after `kill()`: every dispatch fails fast with AgentDown.
    killed: AtomicBool,
    fault: Mutex<Option<FaultPlan>>,
    /// Per-dispatch index feeding `FaultPlan::decide`.
    fault_seq: AtomicU64,
    /// Construction instant; health ages are measured against it.
    started: Instant,
    /// Microseconds-since-`started` of the last completed execution
    /// (`u64::MAX` = never — the sentinel keeps the field lock-free).
    last_beat_us: AtomicU64,
    exec_seq: AtomicU64,
    /// Start instant of every execution currently inside `execute`,
    /// keyed by a monotone token (BTreeMap: the first entry is oldest).
    executing: Mutex<BTreeMap<u64, Instant>>,
}

/// Drop guard bracketing one `execute` call: registers the execution on
/// entry, and on *every* exit path (ok, error, injected drop) removes it
/// and stamps the heartbeat.
struct ExecTracker<'a> {
    agent: &'a FpgaAgent,
    token: u64,
}

impl<'a> ExecTracker<'a> {
    fn begin(agent: &'a FpgaAgent) -> ExecTracker<'a> {
        let token = agent.exec_seq.fetch_add(1, Ordering::Relaxed);
        agent.executing.lock().unwrap().insert(token, Instant::now());
        ExecTracker { agent, token }
    }
}

impl Drop for ExecTracker<'_> {
    fn drop(&mut self) {
        self.agent.executing.lock().unwrap().remove(&self.token);
        let us = self.agent.started.elapsed().as_micros() as u64;
        self.agent.last_beat_us.store(us, Ordering::Release);
    }
}

impl FpgaAgent {
    pub fn new(config: FpgaConfig) -> Arc<FpgaAgent> {
        FpgaAgent::new_named(config, "ultra96-pl")
    }

    /// Like [`FpgaAgent::new`] with an explicit agent name — pool members
    /// need distinct names (`ultra96-pl-0`, `ultra96-pl-1`, ...) so
    /// per-agent reports and queue-processor thread names stay readable.
    pub fn new_named(config: FpgaConfig, name: impl Into<String>) -> Arc<FpgaAgent> {
        let shell = Shell::ultra96(config.num_regions);
        let manager = ReconfigManager::new(shell.regions, config.policy, shell.icap);
        Arc::new(FpgaAgent {
            info: AgentInfo {
                name: name.into(),
                vendor: "xilinx zu3eg (simulated)".into(),
                device_type: DeviceType::Fpga,
                queue_max_size: 1024,
                isa: "zu3eg-pr".into(),
                clock_mhz: crate::fpga::roles::PL_CLOCK_MHZ,
                compute_units: config.num_regions as u32,
            },
            manager: Mutex::new(manager),
            roles: RwLock::new(HashMap::new()),
            virtual_ns: AtomicU64::new(0),
            realtime: config.realtime,
            realtime_scale: config.realtime_scale,
            trace: config.trace,
            killed: AtomicBool::new(false),
            fault: Mutex::new(None),
            fault_seq: AtomicU64::new(0),
            started: Instant::now(),
            last_beat_us: AtomicU64::new(u64::MAX),
            exec_seq: AtomicU64::new(0),
            executing: Mutex::new(BTreeMap::new()),
        })
    }

    pub fn with_defaults() -> Arc<FpgaAgent> {
        FpgaAgent::new(FpgaConfig::default())
    }

    /// Register a pre-synthesized bitstream as a dispatchable kernel.
    /// Returns the kernel-object handle (== the role id).
    pub fn register_role(&self, bitstream: Bitstream, binding: ComputeBinding) -> u64 {
        let id = bitstream.id.0;
        self.roles.write().unwrap().insert(
            id,
            Arc::new(FpgaRole { bitstream, binding, dispatches: AtomicU64::new(0) }),
        );
        id
    }

    pub fn reconfig_stats(&self) -> ReconfigStats {
        self.manager.lock().unwrap().stats()
    }

    /// Queued-demand hint from the serving layer: `queued` requests are
    /// waiting on the role registered as `kernel_object` (0 clears it).
    /// Demand-blind eviction policies ignore the hint; `queue-aware` uses
    /// it to spare roles the batcher is about to dispatch.
    pub fn hint_demand(&self, kernel_object: u64, queued: u64) {
        let role = {
            let map = self.roles.read().unwrap();
            map.get(&kernel_object).map(|r| r.bitstream.id)
        };
        if let Some(id) = role {
            self.manager.lock().unwrap().demand_hint(id, queued);
        }
    }

    pub fn num_regions(&self) -> usize {
        self.manager.lock().unwrap().num_regions()
    }

    /// Whether this agent has at least one unoccupied PR region (a cold
    /// role can load without evicting anything).
    pub fn has_free_region(&self) -> bool {
        self.manager.lock().unwrap().free_regions() > 0
    }

    /// Whether the role registered as `kernel_object` currently occupies a
    /// PR region on *this* agent (false for unknown kernels). The
    /// kernel-affinity router uses this to steer dispatches toward agents
    /// that can skip reconfiguration.
    pub fn is_resident(&self, kernel_object: u64) -> bool {
        let role = {
            let map = self.roles.read().unwrap();
            map.get(&kernel_object).map(|r| r.bitstream.id)
        };
        match role {
            Some(id) => self.manager.lock().unwrap().region_of(id).is_some(),
            None => false,
        }
    }

    /// Whether this agent's single ICAP port is mid-transaction (a
    /// background prefetch still streaming on the virtual clock). The
    /// router treats such agents as expensive for non-resident kernels.
    pub fn icap_busy(&self) -> bool {
        self.manager.lock().unwrap().icap_busy()
    }

    /// Coarse reconfiguration-cost probe for the router: what would
    /// dispatching `kernel_object` here cost right now? Cheapest first
    /// (see [`CostClass`]); unknown kernels rank as [`CostClass::MustEvict`]
    /// — the router never routes unregistered kernels anyway.
    pub fn reconfig_cost(&self, kernel_object: u64) -> CostClass {
        let role = {
            let map = self.roles.read().unwrap();
            map.get(&kernel_object).map(|r| r.bitstream.id)
        };
        match role {
            Some(id) => self.manager.lock().unwrap().cost_of(id),
            None => CostClass::MustEvict,
        }
    }

    /// Non-blocking background load of `kernel_object`'s bitstream (see
    /// [`ReconfigManager::try_prefetch`]). `protected` lists kernel
    /// objects that must not be evicted — the in-flight dispatch and
    /// everything the horizon needs sooner than this one.
    pub fn try_prefetch(
        &self,
        kernel_object: u64,
        protected: &[u64],
        min_free_regions: usize,
        deadline_hint: u64,
    ) -> Prefetch {
        let bitstream = {
            let map = self.roles.read().unwrap();
            map.get(&kernel_object).map(|r| r.bitstream.clone())
        };
        let Some(bitstream) = bitstream else {
            return Prefetch::UnknownKernel;
        };
        // Kernel objects are role ids (see register_role), so the
        // protected set maps directly.
        let protected: Vec<RoleId> = protected.iter().map(|&k| RoleId(k)).collect();
        self.manager.lock().unwrap().try_prefetch(
            &bitstream,
            &protected,
            min_free_regions,
            deadline_hint,
        )
    }

    /// Age the eviction policy's queued-demand hints by one retired
    /// serving batch (see `EvictionPolicy::decay_demand`).
    pub fn decay_demand(&self) {
        self.manager.lock().unwrap().decay_demand();
    }

    /// Dispatch counts per registered role (diagnostics). Sorted by role
    /// name so multi-agent comparisons are order-stable.
    pub fn role_dispatches(&self) -> Vec<(String, u64)> {
        let mut out: Vec<(String, u64)> = self
            .roles
            .read()
            .unwrap()
            .values()
            .map(|r| (r.bitstream.name.clone(), r.dispatches.load(Ordering::Relaxed)))
            .collect();
        out.sort();
        out
    }

    /// Mark the agent dead: every dispatch from now on fails fast with an
    /// agent-down error (the packet processor still retires the packet, so
    /// waiters see the failure instead of hanging). Executions already
    /// inside `execute` run to completion.
    pub fn kill(&self) {
        self.killed.store(true, Ordering::Release);
    }

    /// Bring a killed agent back; dispatches succeed again and the router
    /// re-admits it on its next health check.
    pub fn revive(&self) {
        self.killed.store(false, Ordering::Release);
    }

    pub fn is_alive(&self) -> bool {
        !self.killed.load(Ordering::Acquire)
    }

    /// Install a deterministic fault schedule (replacing any existing one).
    pub fn inject_faults(&self, plan: FaultPlan) {
        *self.fault.lock().unwrap() = Some(plan);
    }

    /// Remove the fault schedule; subsequent dispatches run clean.
    pub fn clear_faults(&self) {
        *self.fault.lock().unwrap() = None;
    }

    fn next_fault(&self) -> Option<Fault> {
        let plan = self.fault.lock().unwrap().clone()?;
        let index = self.fault_seq.fetch_add(1, Ordering::Relaxed);
        plan.decide(index)
    }

    /// Health probe: liveness, in-flight executions and their ages. Cheap
    /// enough for the router to call on every probe interval.
    pub fn health(&self) -> AgentHealth {
        let now = Instant::now();
        let (executing, oldest) = {
            let map = self.executing.lock().unwrap();
            let oldest = map
                .values()
                .min()
                .map(|start| now.saturating_duration_since(*start));
            (map.len() as u64, oldest)
        };
        let beat = self.last_beat_us.load(Ordering::Acquire);
        let heartbeat_age = if beat == u64::MAX {
            None
        } else {
            let now_us = self.started.elapsed().as_micros() as u64;
            Some(Duration::from_micros(now_us.saturating_sub(beat)))
        };
        AgentHealth {
            alive: self.is_alive(),
            executing,
            heartbeat_age,
            oldest_inflight_age: oldest,
        }
    }

    /// Age of the oldest execution still in flight (None when idle).
    pub fn oldest_inflight_age(&self) -> Option<Duration> {
        let map = self.executing.lock().unwrap();
        let now = Instant::now();
        map.values().min().map(|start| now.saturating_duration_since(*start))
    }

    fn sleep_scaled(&self, us: u64) {
        if self.realtime && us > 0 {
            let dur = std::time::Duration::from_micros(
                (us as f64 * self.realtime_scale) as u64,
            );
            std::thread::sleep(dur);
        }
    }
}

impl Agent for FpgaAgent {
    fn info(&self) -> &AgentInfo {
        &self.info
    }

    fn execute(&self, packet: &KernelDispatchPacket) -> Result<()> {
        if !self.is_alive() {
            return Err(HsaError::AgentDown(self.info.name.clone()));
        }
        // Track the execution for health probes; the guard's Drop also
        // stamps the heartbeat on every return path below.
        let _track = ExecTracker::begin(self);
        let fault = self.next_fault();
        match fault {
            Some(Fault::Drop) => {
                return Err(HsaError::AgentDown(self.info.name.clone()));
            }
            Some(Fault::Stall(d)) => {
                // Stall *before* any work: the in-flight age grows past
                // the router's threshold while nothing completes — the
                // wedged-agent signature. If the agent was killed during
                // the stall, fail like a death mid-execution.
                std::thread::sleep(d);
                if !self.is_alive() {
                    return Err(HsaError::AgentDown(self.info.name.clone()));
                }
            }
            _ => {}
        }
        let role = {
            let map = self.roles.read().unwrap();
            map.get(&packet.kernel_object)
                .cloned()
                .ok_or(HsaError::UnknownKernel(packet.kernel_object))?
        };

        // Residency / partial reconfiguration (paper: "happens every time
        // when a kernel that is not currently loaded on the FPGA is
        // executed").
        let outcome: LoadOutcome = {
            let mut mgr = self.manager.lock().unwrap();
            mgr.ensure_loaded(&role.bitstream)?
        };
        // Only the *exposed* ICAP time lands on the dispatch: a full
        // reconfiguration on a reactive miss, the residual transfer on a
        // hit whose prefetch is still streaming, nothing on a clean hit.
        let stall_us = outcome.stall_us();
        if stall_us > 0 {
            self.virtual_ns.fetch_add(stall_us * 1000, Ordering::Relaxed);
            self.sleep_scaled(stall_us);
            if let Some(tr) = &self.trace {
                tr.record_ending_now(
                    crate::trace::recorder::EventKind::Reconfig,
                    format!(
                        "reconfig[{}]:{}",
                        outcome.attribution(),
                        role.bitstream.name
                    ),
                    "fpga-pl",
                    outcome.region() as u32,
                    stall_us,
                );
            }
        }

        // Numerics.
        let outputs = match &role.binding {
            ComputeBinding::Pjrt { handle, module } => {
                handle.execute(module, packet.args.inputs.clone())?
            }
            ComputeBinding::Native(f) => f(&packet.args.inputs)?,
            ComputeBinding::PjrtOrNative { handle, module, signature, native } => {
                if ComputeBinding::signature_matches(signature, &packet.args.inputs) {
                    // PJRT failures (module skipped at load, service gone)
                    // degrade to the native kernel — identical math.
                    match handle.execute(module, packet.args.inputs.clone()) {
                        Ok(outs) => outs,
                        Err(e) => {
                            eprintln!(
                                "fpga: PJRT execute '{module}' failed, \
                                 using native kernel: {e}"
                            );
                            native(&packet.args.inputs)?
                        }
                    }
                } else {
                    native(&packet.args.inputs)?
                }
            }
        };

        // Datapath timing for the actual workload shape.
        let spec = &role.bitstream.spec;
        let op = spec
            .op
            .with_input_shape(&packet.args.inputs)
            .unwrap_or(spec.op);
        let exec_ns = spec.exec_ns(&op);
        self.virtual_ns.fetch_add(exec_ns, Ordering::Relaxed);
        self.sleep_scaled(exec_ns / 1000);
        // Advance the manager's virtual ICAP clock by the modeled compute
        // time: a background prefetch on another region progresses while
        // this one executes — that is the overlap the scheduler buys.
        self.manager
            .lock()
            .unwrap()
            .advance_clock((exec_ns / 1000).max(1));
        if let Some(tr) = &self.trace {
            tr.record_ending_now(
                crate::trace::recorder::EventKind::KernelExec,
                role.bitstream.name.clone(),
                "fpga-pl",
                outcome.region() as u32,
                (exec_ns / 1000).max(1),
            );
        }

        if let Some(Fault::Slow(d)) = fault {
            std::thread::sleep(d);
        }

        role.dispatches.fetch_add(1, Ordering::Relaxed);
        *packet.args.output.lock().unwrap() = Some(Ok(outputs));
        Ok(())
    }

    fn virtual_time_ns(&self) -> u128 {
        self.virtual_ns.load(Ordering::Relaxed) as u128
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::roles::paper_roles;
    use crate::hsa::packet::AqlPacket;
    use crate::hsa::signal::Signal;
    use crate::ops;

    fn native_fc() -> ComputeBinding {
        ComputeBinding::Native(Arc::new(|ins: &[Tensor]| {
            Ok(vec![ops::fc_f32(&ins[0], &ins[1], &ins[2])?])
        }))
    }

    fn echo() -> ComputeBinding {
        ComputeBinding::Native(Arc::new(|ins: &[Tensor]| Ok(ins.to_vec())))
    }

    fn dispatch(agent: &FpgaAgent, obj: u64, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (pkt, args) = AqlPacket::dispatch(obj, inputs, Signal::new(1));
        match pkt {
            AqlPacket::KernelDispatch(d) => {
                agent.execute(&d)?;
                Ok(args.take_output().unwrap().map_err(HsaError::KernelFailed)?)
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn role_dispatch_reconfigures_once_then_hits() {
        let agent = FpgaAgent::with_defaults();
        let roles = paper_roles();
        let id = agent.register_role(roles[0].clone(), native_fc());
        let x = Tensor::zeros(&[64, 64], crate::tf::dtype::DType::F32);
        let w = Tensor::zeros(&[64, 64], crate::tf::dtype::DType::F32);
        let b = Tensor::zeros(&[64], crate::tf::dtype::DType::F32);
        dispatch(&agent, id, vec![x.clone(), w.clone(), b.clone()]).unwrap();
        dispatch(&agent, id, vec![x, w, b]).unwrap();
        let s = agent.reconfig_stats();
        assert_eq!((s.misses, s.hits), (1, 1));
        assert!(s.reconfig_us_total > 7000, "one full reconfig charged");
    }

    #[test]
    fn lru_thrash_across_three_roles_two_regions() {
        let agent = FpgaAgent::with_defaults(); // 2 regions
        let roles = paper_roles();
        let ids: Vec<u64> = roles[..3]
            .iter()
            .map(|r| agent.register_role(r.clone(), echo()))
            .collect();
        let t = Tensor::zeros(&[1, 28, 28], crate::tf::dtype::DType::I16);
        for _ in 0..2 {
            for &id in &ids {
                dispatch(&agent, id, vec![t.clone()]).unwrap();
            }
        }
        let s = agent.reconfig_stats();
        assert_eq!(s.dispatches, 6);
        assert_eq!(s.misses, 6, "cyclic 3-over-2 LRU never hits");
        assert!(s.evictions >= 4);
    }

    #[test]
    fn unknown_role_errors() {
        let agent = FpgaAgent::with_defaults();
        assert!(dispatch(&agent, 0xdead, vec![]).is_err());
    }

    #[test]
    fn virtual_time_includes_reconfig_and_exec() {
        let agent = FpgaAgent::with_defaults();
        let roles = paper_roles();
        let id = agent.register_role(roles[2].clone(), echo());
        let t = Tensor::zeros(&[1, 28, 28], crate::tf::dtype::DType::I16);
        dispatch(&agent, id, vec![t.clone()]).unwrap();
        let after_first = agent.virtual_time_ns();
        assert!(after_first >= 7_000_000, "first dispatch pays ~7.4ms reconfig");
        dispatch(&agent, id, vec![t]).unwrap();
        let delta = agent.virtual_time_ns() - after_first;
        assert!(delta < 100_000, "hit dispatch only pays datapath time, got {delta}");
    }

    #[test]
    fn killed_agent_fails_fast_and_revives() {
        let agent = FpgaAgent::with_defaults();
        let roles = paper_roles();
        let id = agent.register_role(roles[2].clone(), echo());
        let t = Tensor::zeros(&[1, 28, 28], crate::tf::dtype::DType::I16);
        dispatch(&agent, id, vec![t.clone()]).unwrap();
        agent.kill();
        assert!(!agent.is_alive());
        let err = dispatch(&agent, id, vec![t.clone()]).unwrap_err();
        assert!(err.indicates_agent_down(), "{err}");
        assert_eq!(err.agent_down_name(), Some("ultra96-pl"));
        agent.revive();
        dispatch(&agent, id, vec![t]).unwrap();
    }

    #[test]
    fn fault_plan_decisions_are_deterministic_per_index() {
        let plan = FaultPlan {
            seed: 42,
            drop_prob: 0.3,
            stall_prob: 0.2,
            stall: Duration::from_millis(1),
            slow_prob: 0.2,
            slow: Duration::from_millis(1),
        };
        for index in 0..64 {
            let a = format!("{:?}", plan.decide(index));
            let b = format!("{:?}", plan.decide(index));
            assert_eq!(a, b, "decision for index {index} not stable");
        }
        // With these probabilities some dispatch in a short window must
        // fault and some must not (sanity that decide() discriminates).
        let faults = (0..64).filter(|&i| plan.decide(i).is_some()).count();
        assert!(faults > 10 && faults < 60, "{faults}/64 faulted");
    }

    #[test]
    fn injected_drop_fault_surfaces_as_agent_down() {
        let agent = FpgaAgent::with_defaults();
        let roles = paper_roles();
        let id = agent.register_role(roles[2].clone(), echo());
        agent.inject_faults(FaultPlan {
            drop_prob: 1.0,
            ..FaultPlan::none(7)
        });
        let t = Tensor::zeros(&[1, 28, 28], crate::tf::dtype::DType::I16);
        let err = dispatch(&agent, id, vec![t.clone()]).unwrap_err();
        assert!(err.indicates_agent_down(), "{err}");
        agent.clear_faults();
        dispatch(&agent, id, vec![t]).unwrap();
    }

    #[test]
    fn health_probe_tracks_heartbeat_and_inflight() {
        let agent = FpgaAgent::with_defaults();
        let h = agent.health();
        assert!(h.alive);
        assert_eq!(h.executing, 0);
        assert!(h.heartbeat_age.is_none(), "no execution yet");
        assert!(h.oldest_inflight_age.is_none());

        let roles = paper_roles();
        let id = agent.register_role(roles[2].clone(), echo());
        let t = Tensor::zeros(&[1, 28, 28], crate::tf::dtype::DType::I16);
        dispatch(&agent, id, vec![t]).unwrap();
        let h = agent.health();
        assert_eq!(h.executing, 0);
        assert!(h.heartbeat_age.is_some(), "completed execution stamps a beat");

        // A stalled execution shows up as a growing in-flight age.
        agent.inject_faults(FaultPlan {
            stall_prob: 1.0,
            stall: Duration::from_millis(80),
            ..FaultPlan::none(1)
        });
        let agent2 = Arc::clone(&agent);
        let t2 = Tensor::zeros(&[1, 28, 28], crate::tf::dtype::DType::I16);
        let handle = std::thread::spawn(move || {
            let _ = dispatch(&agent2, id, vec![t2]);
        });
        std::thread::sleep(Duration::from_millis(30));
        let h = agent.health();
        assert_eq!(h.executing, 1, "stalled dispatch is in flight");
        assert!(
            h.oldest_inflight_age.unwrap() >= Duration::from_millis(10),
            "{h:?}"
        );
        handle.join().unwrap();
        assert_eq!(agent.health().executing, 0);
    }

    #[test]
    fn numerics_flow_through_binding() {
        let agent = FpgaAgent::with_defaults();
        let roles = paper_roles();
        let id = agent.register_role(roles[0].clone(), native_fc());
        let x = Tensor::from_f32(&[1, 2], vec![1.0, 2.0]).unwrap();
        let w = Tensor::from_f32(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let b = Tensor::from_f32(&[2], vec![0.5, -0.5]).unwrap();
        let out = dispatch(&agent, id, vec![x, w, b]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[1.5, 1.5]);
    }
}
