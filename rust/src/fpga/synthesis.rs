//! Resource estimation — regenerates Table I.
//!
//! A role netlist is modeled as a bag of datapath *components* with
//! per-component LUT/FF/BRAM/DSP costs. The cost table is calibrated
//! against the paper's Vivado results (Table I) so that the shell and the
//! four roles reproduce the published rows; the estimator then extrapolates
//! sensibly when roles are modified (more taps, more filters, wider MACs),
//! which the ablation benches exercise.
//!
//! Fixed-weight multipliers are classified LUT-vs-DSP the way a synthesizer
//! would: a constant multiplier whose canonical-signed-digit (CSD) form has
//! few nonzero digits becomes a short shift/add chain in LUTs; "hard"
//! constants keep a DSP48. See [`csd_terms`].

use crate::fpga::resources::ResourceVector;

/// Datapath building blocks with calibrated synthesis costs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Component {
    /// Role control FSM + microcode store.
    ControlFsm,
    /// One AXI4-Stream endpoint (in or out).
    AxiStreamIf,
    /// One float32 multiply-accumulate unit (mantissa mult in DSPs,
    /// alignment/normalization in LUTs).
    F32Mac,
    /// Barrier synchronization stage (role 2).
    BarrierSync,
    /// LUTRAM ping-pong output buffer (role 1's full pipelining).
    DoubleBuffer,
    /// On-chip weight store of `kb` kibibytes.
    WeightBuffer { kb: u32 },
    /// Stream FIFO of `kb` kibibytes.
    StreamFifo { kb: u32 },
    /// One fixed-weight int16 tap mapped to LUT shift/add logic.
    I16TapLut,
    /// One fixed-weight int16 tap kept on a DSP48.
    I16TapDsp,
    /// One node of the accumulation adder tree.
    AdderTreeNode,
    /// Convolution line buffer holding `rows` image rows.
    LineBuffer { rows: u32 },
    /// Requantize + saturate stage (int16 output).
    QuantSat,
    /// Per-filter replication overhead: private accumulator pipeline,
    /// writeback DMA descriptor generator (multi-filter conv roles).
    FilterPipeline,
    /// N-way output stream multiplexer.
    OutputMux { ways: u32 },
    /// Shell parts (static logic, not inside any role).
    AxiInterconnect,
    DmaEngine,
    PcapController,
    DoorbellMmio,
}

/// Bytes per BRAM36 (36 Kib = 4.5 KiB).
const BRAM36_KIB: f64 = 4.5;

fn brams_for_kib(kb: u32) -> u32 {
    (kb as f64 / BRAM36_KIB).ceil() as u32
}

impl Component {
    /// Calibrated synthesis cost of this component.
    pub fn cost(&self) -> ResourceVector {
        use Component::*;
        match *self {
            ControlFsm => ResourceVector::new(890, 580, 1, 0),
            AxiStreamIf => ResourceVector::new(650, 580, 2, 0),
            F32Mac => ResourceVector::new(1560, 1300, 0, 2),
            BarrierSync => ResourceVector::new(501, 451, 0, 0),
            DoubleBuffer => ResourceVector::new(984, 704, 0, 0),
            WeightBuffer { kb } => ResourceVector::new(210, 180, brams_for_kib(kb), 0),
            StreamFifo { kb } => ResourceVector::new(180, 140, brams_for_kib(kb), 0),
            I16TapLut => ResourceVector::new(60, 68, 0, 0),
            I16TapDsp => ResourceVector::new(25, 40, 0, 1),
            AdderTreeNode => ResourceVector::new(40, 47, 0, 0),
            LineBuffer { rows } => ResourceVector::new(120, 130, rows, 0),
            QuantSat => ResourceVector::new(171, 125, 0, 0),
            FilterPipeline => ResourceVector::new(1474, 1653, 0, 0),
            OutputMux { ways } => ResourceVector::new(310 * ways, 290 * ways, 0, 0),
            AxiInterconnect => ResourceVector::new(3200, 2800, 2, 0),
            DmaEngine => ResourceVector::new(2200, 1900, 3, 0),
            PcapController => ResourceVector::new(1317, 1144, 0, 0),
            DoorbellMmio => ResourceVector::new(998, 800, 2, 0),
        }
    }
}

/// Estimate the synthesis result of a netlist (bag of components).
pub fn estimate(components: &[Component]) -> ResourceVector {
    components
        .iter()
        .fold(ResourceVector::ZERO, |acc, c| acc + c.cost())
}

/// Number of nonzero digits in the canonical signed-digit representation of
/// `w` — the cost metric for constant multipliers. CSD recoding guarantees
/// no two adjacent nonzero digits; a constant with `t` nonzero digits costs
/// `t-1` adders as LUT logic.
pub fn csd_terms(w: i32) -> u32 {
    let mut v: i64 = (w as i64).abs();
    let mut terms = 0u32;
    while v != 0 {
        if v & 1 != 0 {
            // Round to the nearest multiple of 4 (standard CSD recoding):
            // ±1 chosen so the next two bits are clear.
            if v & 3 == 3 {
                v += 1; // digit -1
            } else {
                v -= 1; // digit +1
            }
            terms += 1;
        }
        v >>= 1;
    }
    terms
}

/// Split fixed taps between LUT shift/add chains and DSP48s. Taps with at
/// most `lut_threshold` CSD terms synthesize to adders; the rest keep DSPs.
pub fn classify_taps(weights: &[i32], lut_threshold: u32) -> (usize, usize) {
    let mut lut = 0;
    let mut dsp = 0;
    for &w in weights {
        if csd_terms(w) <= lut_threshold {
            lut += 1;
        } else {
            dsp += 1;
        }
    }
    (lut, dsp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csd_of_powers_of_two_is_one_term() {
        for sh in 0..14 {
            assert_eq!(csd_terms(1 << sh), 1, "2^{sh}");
        }
    }

    #[test]
    fn csd_of_zero_is_zero() {
        assert_eq!(csd_terms(0), 0);
    }

    #[test]
    fn csd_uses_signed_digits() {
        // 15 = 16 - 1 -> 2 terms (binary would need 4).
        assert_eq!(csd_terms(15), 2);
        // 7 = 8 - 1.
        assert_eq!(csd_terms(7), 2);
        // 5 = 4 + 1.
        assert_eq!(csd_terms(5), 2);
        // 11 = 8 + 2 + 1 or 16-4-1 -> 3 terms.
        assert_eq!(csd_terms(11), 3);
    }

    #[test]
    fn csd_symmetric_in_sign() {
        for w in [-127, -64, -11, -1, 1, 11, 64, 127] {
            assert_eq!(csd_terms(w), csd_terms(-w));
        }
    }

    #[test]
    fn classify_splits_all_taps() {
        let ws: Vec<i32> = (-12..13).collect();
        let (l, d) = classify_taps(&ws, 2);
        assert_eq!(l + d, ws.len());
        assert!(l > 0);
    }

    #[test]
    fn estimate_sums_components() {
        let est = estimate(&[Component::ControlFsm, Component::F32Mac]);
        assert_eq!(est, Component::ControlFsm.cost() + Component::F32Mac.cost());
    }

    #[test]
    fn bram_rounding_up() {
        assert_eq!(brams_for_kib(1), 1);
        assert_eq!(brams_for_kib(5), 2);
        assert_eq!(brams_for_kib(9), 2);
        assert_eq!(brams_for_kib(10), 3);
    }
}
