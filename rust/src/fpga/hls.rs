//! Online-synthesis ("OpenCL kernel") flow — the paper's rejected
//! alternative (§III): *"The simple and most flexible solution would be an
//! OpenCL implementation … After a runtime synthesis the device specific
//! bitstream is generated and deployed … this approach leads to a
//! significant increase in runtime and energy costs."*
//!
//! We model that flow so the trade-off is quantifiable: an OpenCL-style
//! kernel description goes through HLS scheduling + logic synthesis +
//! place&route *at dispatch time* (on the embedded A53, which is what makes
//! it so expensive), then the resulting bitstream follows the normal
//! partial-reconfiguration path. The cost model is calibrated to
//! small-design Vivado runs on embedded-class hosts (tens of minutes) —
//! see DESIGN.md §8.

use crate::fpga::bitstream::Bitstream;
use crate::fpga::datapath::DatapathSpec;
use crate::fpga::resources::ResourceVector;
use crate::fpga::roles::ROLE_BITSTREAM_BYTES;
use crate::fpga::synthesis::{estimate, Component};

/// Cost model of on-device synthesis.
#[derive(Debug, Clone)]
pub struct HlsCostModel {
    /// Fixed front-end cost (OpenCL -> RTL scheduling/binding), seconds.
    pub hls_base_s: f64,
    /// Logic synthesis seconds per kLUT.
    pub synth_s_per_klut: f64,
    /// Place&route seconds per kLUT (dominant; embedded-class host).
    pub pnr_s_per_klut: f64,
    /// Bitgen fixed cost, seconds.
    pub bitgen_s: f64,
    /// Host (A53 cluster) active power during synthesis, watts.
    pub host_active_w: f64,
    /// PL static+config power during reconfiguration, watts.
    pub reconfig_w: f64,
}

impl Default for HlsCostModel {
    fn default() -> Self {
        HlsCostModel {
            hls_base_s: 95.0,
            synth_s_per_klut: 28.0,
            pnr_s_per_klut: 55.0,
            bitgen_s: 40.0,
            host_active_w: 4.2,
            reconfig_w: 0.35,
        }
    }
}

/// Result of an online synthesis run.
#[derive(Debug, Clone)]
pub struct SynthesisRun {
    pub bitstream: Bitstream,
    pub synthesis_s: f64,
    pub synthesis_energy_j: f64,
}

/// Aggregate comparison of the two flows over a deployment of `dispatches`
/// kernel invocations (the paper's argument, quantified).
#[derive(Debug, Clone)]
pub struct FlowComparison {
    pub dispatches: u64,
    /// Pre-synthesized flow: reconfiguration only.
    pub presynth_total_s: f64,
    pub presynth_energy_j: f64,
    /// Online flow: synthesis once + the same reconfiguration.
    pub online_total_s: f64,
    pub online_energy_j: f64,
}

impl FlowComparison {
    pub fn overhead_factor(&self) -> f64 {
        self.online_total_s / self.presynth_total_s.max(1e-12)
    }
    pub fn energy_factor(&self) -> f64 {
        self.online_energy_j / self.presynth_energy_j.max(1e-12)
    }
}

/// The online-synthesis flow.
#[derive(Debug, Clone, Default)]
pub struct HlsFlow {
    pub model: HlsCostModel,
}

impl HlsFlow {
    pub fn new(model: HlsCostModel) -> HlsFlow {
        HlsFlow { model }
    }

    /// Synthesize a kernel described by `components` + `spec` into a
    /// deployable bitstream, modeling the on-device cost.
    pub fn synthesize(
        &self,
        name: &str,
        components: &[Component],
        spec: DatapathSpec,
    ) -> SynthesisRun {
        let resources = estimate(components);
        let s = self.synthesis_seconds(&resources);
        SynthesisRun {
            bitstream: Bitstream::new(name, ROLE_BITSTREAM_BYTES, resources, spec),
            synthesis_s: s,
            synthesis_energy_j: s * self.model.host_active_w,
        }
    }

    /// Seconds of on-device HLS + synthesis + P&R + bitgen.
    pub fn synthesis_seconds(&self, resources: &ResourceVector) -> f64 {
        let kluts = resources.luts as f64 / 1000.0;
        self.model.hls_base_s
            + kluts * (self.model.synth_s_per_klut + self.model.pnr_s_per_klut)
            + self.model.bitgen_s
    }

    /// Compare pre-synthesized vs online flows for a role that is
    /// dispatched `dispatches` times with `reconfigs` actual PCAP loads
    /// (the rest are residency hits).
    pub fn compare(
        &self,
        resources: &ResourceVector,
        reconfig_us: u64,
        dispatches: u64,
        reconfigs: u64,
    ) -> FlowComparison {
        let reconfig_s = reconfigs as f64 * reconfig_us as f64 / 1e6;
        let reconfig_j = reconfig_s * self.model.reconfig_w;
        let synth_s = self.synthesis_seconds(resources);
        FlowComparison {
            dispatches,
            presynth_total_s: reconfig_s,
            presynth_energy_j: reconfig_j,
            online_total_s: synth_s + reconfig_s,
            online_energy_j: synth_s * self.model.host_active_w + reconfig_j,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::roles;

    #[test]
    fn synthesis_time_scales_with_design_size() {
        let flow = HlsFlow::default();
        let small = flow.synthesis_seconds(&ResourceVector::new(1000, 1000, 2, 1));
        let big = flow.synthesis_seconds(&ResourceVector::new(10000, 9000, 20, 10));
        assert!(big > small);
        // Minutes, not milliseconds: that's the paper's point.
        assert!(small > 100.0, "even a tiny kernel takes minutes: {small}");
    }

    #[test]
    fn synthesize_produces_deployable_bitstream() {
        let flow = HlsFlow::default();
        let run = flow.synthesize(
            "opencl_preproc",
            &roles::role3_components(),
            roles::role3_spec(),
        );
        assert_eq!(run.bitstream.resources, estimate(&roles::role3_components()));
        assert!(run.synthesis_s > 0.0);
        assert!(run.synthesis_energy_j > run.synthesis_s, "4.2 W host power");
    }

    #[test]
    fn online_flow_dominated_by_synthesis() {
        // The paper's claim: online synthesis costs orders of magnitude
        // more time and energy than deploying a pre-synthesized bitstream.
        let flow = HlsFlow::default();
        let res = estimate(&roles::role3_components());
        let cmp = flow.compare(&res, 7425, 1000, 1);
        assert!(
            cmp.overhead_factor() > 1000.0,
            "online/presynth time factor {}",
            cmp.overhead_factor()
        );
        assert!(
            cmp.energy_factor() > 10_000.0,
            "energy factor {}",
            cmp.energy_factor()
        );
    }

    #[test]
    fn amortization_shrinks_with_reuse_but_stays_dominant() {
        let flow = HlsFlow::default();
        let res = estimate(&roles::role1_components());
        let few = flow.compare(&res, 7425, 10, 10);
        let many = flow.compare(&res, 7425, 100_000, 100_000);
        assert!(many.overhead_factor() < few.overhead_factor());
        assert!(many.overhead_factor() > 1.0);
    }
}
