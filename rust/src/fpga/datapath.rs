//! Role datapath cycle models — Table III's FPGA-side numbers.
//!
//! A role is a fixed-function streaming datapath: an AXI front-end feeds a
//! MAC array; results drain through an output FIFO. Cycle counts follow the
//! standard pipelined-accelerator formula
//!
//! ```text
//! cycles = ceil(total_macs / (macs_per_cycle / ii))
//!        + pipeline_depth                      (fill/drain)
//!        + bursts * burst_overhead             (AXI handshakes)
//!        + barriers * barrier_stall            (role 2 only)
//! ```
//!
//! The per-role parallelism (`macs_per_cycle`) comes from the datapath
//! structure (tap count, PE count); stall parameters are calibrated against
//! the paper's Table III and documented in DESIGN.md §6.

use crate::tf::tensor::Tensor;

/// Compute shape of a role. Dimensions that the paper fixes (filter sizes,
/// weight constants) are part of the variant, not the workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoleOp {
    /// Dense `x(M,K) @ w(K,N) + b`; weights fixed at synthesis.
    FcF32 { m: usize, k: usize, n: usize },
    /// Valid 2-D convolution with fixed weights, int16 in / int16 out.
    ConvI16 { cin: usize, h: usize, w: usize, kh: usize, kw: usize, filters: usize },
    /// Generic streaming op (used by the OpenCL-style multi-tenant clients):
    /// `ops_per_element` operations over `elements` stream elements.
    Stream { elements: usize, ops_per_element: usize },
}

impl RoleOp {
    /// Multiply-accumulate count of the workload.
    pub fn macs(&self) -> u64 {
        match *self {
            RoleOp::FcF32 { m, k, n } => (m * k * n) as u64,
            RoleOp::ConvI16 { cin, h, w, kh, kw, filters } => {
                let oh = h - kh + 1;
                let ow = w - kw + 1;
                (filters * cin * oh * ow * kh * kw) as u64
            }
            RoleOp::Stream { elements, ops_per_element } => {
                (elements * ops_per_element) as u64 / 2
            }
        }
    }

    /// Total arithmetic operations (1 MAC = 2 OPs: multiply + add); Table
    /// III counts operations.
    pub fn ops(&self) -> u64 {
        self.macs() * 2
    }

    /// Bytes streamed in + out (for AXI burst accounting).
    pub fn stream_bytes(&self) -> u64 {
        match *self {
            RoleOp::FcF32 { m, k, n } => ((m * k + m * n) * 4) as u64,
            RoleOp::ConvI16 { cin, h, w, kh, kw, filters } => {
                let oh = h - kh + 1;
                let ow = w - kw + 1;
                ((cin * h * w + filters * oh * ow) * 2) as u64
            }
            RoleOp::Stream { elements, .. } => (elements * 8) as u64,
        }
    }

    /// Derive the workload from dispatch inputs, keeping the variant's
    /// fixed structure. Returns `None` if the input rank is incompatible.
    pub fn with_input_shape(&self, inputs: &[Tensor]) -> Option<RoleOp> {
        let first = inputs.first()?;
        match *self {
            RoleOp::FcF32 { k, n, .. } => {
                let s = first.shape();
                if s.len() == 2 && s[1] == k {
                    Some(RoleOp::FcF32 { m: s[0], k, n })
                } else {
                    None
                }
            }
            RoleOp::ConvI16 { kh, kw, filters, .. } => {
                let s = first.shape();
                if s.len() == 3 && s[1] >= kh && s[2] >= kw {
                    Some(RoleOp::ConvI16 {
                        cin: s[0],
                        h: s[1],
                        w: s[2],
                        kh,
                        kw,
                        filters,
                    })
                } else {
                    None
                }
            }
            RoleOp::Stream { ops_per_element, .. } => Some(RoleOp::Stream {
                elements: first.len(),
                ops_per_element,
            }),
        }
    }
}

/// Structural + timing description of a role's datapath.
#[derive(Debug, Clone)]
pub struct DatapathSpec {
    pub name: &'static str,
    /// Nominal workload (the paper's benchmark shape for this role).
    pub op: RoleOp,
    /// Parallel MAC units physically instantiated.
    pub macs_per_cycle: u32,
    /// Initiation interval (cycles between accepted inputs).
    pub ii: u32,
    /// Pipeline fill/drain latency in cycles.
    pub pipeline_depth: u32,
    /// AXI burst length in bytes and fixed handshake cost per burst.
    pub burst_bytes: u32,
    pub burst_overhead_cycles: u32,
    /// Role-2 style barrier: number of synchronization points per pass and
    /// the stall each one costs (0 for barrier-free roles).
    pub barriers_per_pass: u32,
    pub barrier_stall_cycles: u32,
    /// PL clock this role closes timing at.
    pub clock_mhz: u32,
}

impl DatapathSpec {
    /// Total datapath cycles for `op` on this role.
    pub fn cycles(&self, op: &RoleOp) -> u64 {
        let throughput_macs_per_cycle = self.macs_per_cycle as u64;
        let compute =
            (op.macs() * self.ii as u64).div_ceil(throughput_macs_per_cycle.max(1));
        let bursts = op.stream_bytes().div_ceil(self.burst_bytes.max(1) as u64);
        compute
            + self.pipeline_depth as u64
            + bursts * self.burst_overhead_cycles as u64
            + self.barriers_per_pass as u64 * self.barrier_stall_cycles as u64
    }

    /// Nanoseconds for `op` at the role's clock.
    pub fn exec_ns(&self, op: &RoleOp) -> u64 {
        let cycles = self.cycles(op);
        // ns = cycles / (MHz) * 1000
        cycles * 1000 / self.clock_mhz.max(1) as u64
    }

    /// Achieved operations per cycle for `op` (Table III's metric).
    pub fn ops_per_cycle(&self, op: &RoleOp) -> f64 {
        op.ops() as f64 / self.cycles(op) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fc_spec() -> DatapathSpec {
        DatapathSpec {
            name: "fc",
            op: RoleOp::FcF32 { m: 64, k: 64, n: 64 },
            macs_per_cycle: 4,
            ii: 1,
            pipeline_depth: 32,
            burst_bytes: 4096,
            burst_overhead_cycles: 8,
            barriers_per_pass: 0,
            barrier_stall_cycles: 0,
            clock_mhz: 150,
        }
    }

    #[test]
    fn fc_mac_count() {
        let op = RoleOp::FcF32 { m: 64, k: 64, n: 64 };
        assert_eq!(op.macs(), 64 * 64 * 64);
        assert_eq!(op.ops(), 2 * 64 * 64 * 64);
    }

    #[test]
    fn conv_mac_count() {
        let op = RoleOp::ConvI16 { cin: 1, h: 28, w: 28, kh: 5, kw: 5, filters: 1 };
        assert_eq!(op.macs(), 24 * 24 * 25);
    }

    #[test]
    fn cycles_dominated_by_compute() {
        let s = fc_spec();
        let c = s.cycles(&s.op);
        let compute = 64u64 * 64 * 64 / 4;
        assert!(c >= compute && c < compute + compute / 4, "cycles {c}");
    }

    #[test]
    fn barrier_adds_stalls() {
        let mut s = fc_spec();
        let base = s.cycles(&s.op);
        s.barriers_per_pass = 64;
        s.barrier_stall_cycles = 100;
        assert_eq!(s.cycles(&s.op), base + 6400);
    }

    #[test]
    fn ops_per_cycle_bounded_by_peak() {
        let s = fc_spec();
        let opc = s.ops_per_cycle(&s.op);
        assert!(opc > 0.0 && opc <= (2 * s.macs_per_cycle) as f64, "{opc}");
    }

    #[test]
    fn workload_rescales_with_input_shape() {
        let s = fc_spec();
        let t = Tensor::zeros(&[128, 64], crate::tf::dtype::DType::F32);
        let op = s.op.with_input_shape(std::slice::from_ref(&t)).unwrap();
        assert_eq!(op, RoleOp::FcF32 { m: 128, k: 64, n: 64 });
        // Incompatible contraction dim is rejected.
        let bad = Tensor::zeros(&[128, 63], crate::tf::dtype::DType::F32);
        assert!(s.op.with_input_shape(std::slice::from_ref(&bad)).is_none());
    }

    #[test]
    fn exec_ns_scales_with_clock() {
        let mut s = fc_spec();
        let t150 = s.exec_ns(&s.op);
        s.clock_mhz = 300;
        assert!(s.exec_ns(&s.op) < t150);
    }
}
