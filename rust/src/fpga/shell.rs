//! The static shell: floorplan, PR region partitions, configuration port.
//!
//! The shell is the always-resident part of the PL design (Table I row 1):
//! AXI interconnect, DMA engines, the PCAP/PR controller and the queue
//! doorbell block. It owns the floorplan — how many PR regions exist and
//! how much of the device each one gets.

use crate::fpga::icap::Icap;
use crate::fpga::region::PrRegion;
use crate::fpga::resources::{ResourceVector, ZU3EG};
use crate::fpga::roles::shell_resources;

/// Floorplan + static logic of the FPGA design.
#[derive(Debug)]
pub struct Shell {
    pub device: ResourceVector,
    pub static_resources: ResourceVector,
    pub regions: Vec<PrRegion>,
    pub icap: Icap,
}

impl Shell {
    /// The paper's Ultra96 shell with `num_regions` equal PR partitions
    /// carved out of the device resources left after the static logic.
    pub fn ultra96(num_regions: usize) -> Shell {
        assert!(num_regions >= 1, "at least one PR region");
        let stat = shell_resources();
        let remaining = ZU3EG.saturating_sub(&stat);
        let per_region = ResourceVector {
            luts: remaining.luts / num_regions as u32,
            ffs: remaining.ffs / num_regions as u32,
            bram36: remaining.bram36 / num_regions as u32,
            dsps: remaining.dsps / num_regions as u32,
        };
        let regions = (0..num_regions)
            .map(|i| PrRegion::new(i, per_region))
            .collect();
        Shell {
            device: ZU3EG,
            static_resources: stat,
            regions,
            icap: Icap::default(),
        }
    }

    /// Total resources currently accounted (static + capacity granted to
    /// regions) — must never exceed the device.
    pub fn budget_consistent(&self) -> bool {
        let mut total = self.static_resources;
        for r in &self.regions {
            total += r.capacity;
        }
        total.fits_in(&self.device)
    }

    pub fn num_regions(&self) -> usize {
        self.regions.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::roles::paper_roles;

    #[test]
    fn default_floorplan_is_consistent() {
        for n in 1..=4 {
            let s = Shell::ultra96(n);
            assert!(s.budget_consistent(), "{n} regions over budget");
            assert_eq!(s.num_regions(), n);
        }
    }

    #[test]
    fn two_region_floorplan_fits_all_paper_roles() {
        let s = Shell::ultra96(2);
        for role in paper_roles() {
            assert!(
                role.resources.fits_in(&s.regions[0].capacity),
                "{} does not fit half-device region",
                role.name
            );
        }
    }

    #[test]
    fn four_region_floorplan_fits_all_paper_roles() {
        let s = Shell::ultra96(4);
        for role in paper_roles() {
            assert!(
                role.resources.fits_in(&s.regions[0].capacity),
                "{} does not fit quarter-device region",
                role.name
            );
        }
    }

    #[test]
    #[should_panic(expected = "at least one")]
    fn zero_regions_rejected() {
        Shell::ultra96(0);
    }
}
