//! Programmable-logic resource vectors (LUT / FF / BRAM36 / DSP48E2).

use std::fmt;
use std::ops::{Add, AddAssign};

/// A bundle of PL resources — one row of Table I.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ResourceVector {
    pub luts: u32,
    pub ffs: u32,
    pub bram36: u32,
    pub dsps: u32,
}

/// The Zynq UltraScale+ ZU3EG device on the Ultra96 board. The paper's
/// Table I percentages confirm these totals exactly (9915 LUT = 14.1 %,
/// 8544 FF = 6.1 %, 10 BRAM = 4.6 %, 8 DSP = 2.2 %).
pub const ZU3EG: ResourceVector = ResourceVector {
    luts: 70_560,
    ffs: 141_120,
    bram36: 216,
    dsps: 360,
};

impl ResourceVector {
    pub const fn new(luts: u32, ffs: u32, bram36: u32, dsps: u32) -> Self {
        ResourceVector { luts, ffs, bram36, dsps }
    }

    pub const ZERO: ResourceVector = ResourceVector::new(0, 0, 0, 0);

    /// Component-wise `self <= other`.
    pub fn fits_in(&self, other: &ResourceVector) -> bool {
        self.luts <= other.luts
            && self.ffs <= other.ffs
            && self.bram36 <= other.bram36
            && self.dsps <= other.dsps
    }

    /// Component-wise saturating subtraction (remaining capacity).
    pub fn saturating_sub(&self, other: &ResourceVector) -> ResourceVector {
        ResourceVector {
            luts: self.luts.saturating_sub(other.luts),
            ffs: self.ffs.saturating_sub(other.ffs),
            bram36: self.bram36.saturating_sub(other.bram36),
            dsps: self.dsps.saturating_sub(other.dsps),
        }
    }

    /// Utilization of each component against a device, in percent.
    pub fn utilization_pct(&self, device: &ResourceVector) -> [f64; 4] {
        let pct = |a: u32, b: u32| if b == 0 { 0.0 } else { 100.0 * a as f64 / b as f64 };
        [
            pct(self.luts, device.luts),
            pct(self.ffs, device.ffs),
            pct(self.bram36, device.bram36),
            pct(self.dsps, device.dsps),
        ]
    }

    /// Format one Table-I-style row: `9915 (14.1%)  8544 (6.1%) ...`.
    pub fn table_row(&self, device: &ResourceVector) -> String {
        let u = self.utilization_pct(device);
        format!(
            "{:>6} ({:>4.1}%) | {:>6} ({:>4.1}%) | {:>4} ({:>4.1}%) | {:>4} ({:>4.1}%)",
            self.luts, u[0], self.ffs, u[1], self.bram36, u[2], self.dsps, u[3]
        )
    }
}

impl Add for ResourceVector {
    type Output = ResourceVector;
    fn add(self, rhs: ResourceVector) -> ResourceVector {
        ResourceVector {
            luts: self.luts + rhs.luts,
            ffs: self.ffs + rhs.ffs,
            bram36: self.bram36 + rhs.bram36,
            dsps: self.dsps + rhs.dsps,
        }
    }
}

impl AddAssign for ResourceVector {
    fn add_assign(&mut self, rhs: ResourceVector) {
        *self = *self + rhs;
    }
}

impl fmt::Display for ResourceVector {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} LUT / {} FF / {} BRAM / {} DSP",
            self.luts, self.ffs, self.bram36, self.dsps
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_percentages_confirm_zu3eg() {
        // Shell row of Table I.
        let shell = ResourceVector::new(9915, 8544, 10, 0);
        let u = shell.utilization_pct(&ZU3EG);
        assert!((u[0] - 14.1).abs() < 0.1, "LUT% {}", u[0]);
        assert!((u[1] - 6.1).abs() < 0.1, "FF% {}", u[1]);
        assert!((u[2] - 4.6).abs() < 0.1, "BRAM% {}", u[2]);
        // Role 2 row.
        let r2 = ResourceVector::new(9501, 7851, 23, 8);
        let u2 = r2.utilization_pct(&ZU3EG);
        assert!((u2[0] - 13.5).abs() < 0.1);
        assert!((u2[1] - 5.6).abs() < 0.1);
        assert!((u2[2] - 10.6).abs() < 0.1);
        assert!((u2[3] - 2.2).abs() < 0.1);
    }

    #[test]
    fn fits_and_subtract() {
        let a = ResourceVector::new(10, 10, 1, 1);
        let b = ResourceVector::new(20, 10, 2, 1);
        assert!(a.fits_in(&b));
        assert!(!b.fits_in(&a));
        assert_eq!(b.saturating_sub(&a), ResourceVector::new(10, 0, 1, 0));
    }

    #[test]
    fn add_accumulates() {
        let mut v = ResourceVector::ZERO;
        v += ResourceVector::new(1, 2, 3, 4);
        v += ResourceVector::new(10, 20, 30, 40);
        assert_eq!(v, ResourceVector::new(11, 22, 33, 44));
    }

    #[test]
    fn zero_device_is_zero_pct() {
        let v = ResourceVector::new(1, 1, 1, 1);
        assert_eq!(v.utilization_pct(&ResourceVector::ZERO), [0.0; 4]);
    }
}
