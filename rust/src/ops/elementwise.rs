//! Elementwise ops.

use crate::hsa::error::{HsaError, Result};
use crate::tf::tensor::Tensor;

/// Elementwise f32 add (shapes must match).
pub fn add_f32(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    if a.shape() != b.shape() {
        return Err(HsaError::KernelFailed(format!(
            "add shape mismatch {:?} vs {:?}",
            a.shape(),
            b.shape()
        )));
    }
    let av = a.as_f32()?;
    let bv = b.as_f32()?;
    let out: Vec<f32> = av.iter().zip(bv).map(|(x, y)| x + y).collect();
    Ok(Tensor::from_f32(a.shape(), out)?)
}

/// `x (.., N) + b (N,)` — broadcast bias over the last axis.
pub fn bias_add_f32(x: &Tensor, b: &Tensor) -> Result<Tensor> {
    let n = *x.shape().last().ok_or_else(|| {
        HsaError::KernelFailed("bias_add on rank-0 tensor".into())
    })?;
    if b.shape() != [n] {
        return Err(HsaError::KernelFailed(format!(
            "bias shape {:?} != [{n}]",
            b.shape()
        )));
    }
    let xd = x.as_f32()?;
    let bd = b.as_f32()?;
    let out: Vec<f32> = xd
        .iter()
        .enumerate()
        .map(|(i, &v)| v + bd[i % n])
        .collect();
    Ok(Tensor::from_f32(x.shape(), out)?)
}

/// Concatenate f32 tensors along `axis`. All inputs must share rank and
/// every dimension except `axis` (ONNX `Concat` semantics on our
/// batchless layouts).
pub fn concat_f32(inputs: &[&Tensor], axis: usize) -> Result<Tensor> {
    let first = inputs.first().ok_or_else(|| {
        HsaError::KernelFailed("concat needs at least one input".into())
    })?;
    let rank = first.rank();
    if axis >= rank {
        return Err(HsaError::KernelFailed(format!(
            "concat axis {axis} out of range for rank {rank}"
        )));
    }
    let mut out_shape = first.shape().to_vec();
    out_shape[axis] = 0;
    for t in inputs {
        let s = t.shape();
        if s.len() != rank {
            return Err(HsaError::KernelFailed(format!(
                "concat rank mismatch {} vs {rank}",
                s.len()
            )));
        }
        for (d, (&a, &b)) in s.iter().zip(first.shape()).enumerate() {
            if d != axis && a != b {
                return Err(HsaError::KernelFailed(format!(
                    "concat dim {d} mismatch: {s:?} vs {:?} (axis {axis})",
                    first.shape()
                )));
            }
        }
        out_shape[axis] += s[axis];
    }
    // Row-major: copy per "outer block". outer = product of dims before
    // axis; each input contributes a contiguous run of axis*inner elements
    // per outer block.
    let outer: usize = first.shape()[..axis].iter().product();
    let inner: usize = first.shape()[axis + 1..].iter().product();
    let mut out = Vec::with_capacity(out_shape.iter().product());
    let data: Vec<&[f32]> = inputs.iter().map(|t| t.as_f32()).collect::<Result<_>>()?;
    for o in 0..outer {
        for (t, d) in inputs.iter().zip(&data) {
            let run = t.shape()[axis] * inner;
            out.extend_from_slice(&d[o * run..(o + 1) * run]);
        }
    }
    Ok(Tensor::from_f32(&out_shape, out)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concat_axis0_stacks_channels() {
        let a = Tensor::from_f32(&[1, 2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::from_f32(&[2, 2, 2], (5..13).map(|v| v as f32).collect()).unwrap();
        let y = concat_f32(&[&a, &b], 0).unwrap();
        assert_eq!(y.shape(), &[3, 2, 2]);
        assert_eq!(y.as_f32().unwrap(), &(1..13).map(|v| v as f32).collect::<Vec<_>>()[..]);
    }

    #[test]
    fn concat_inner_axis_interleaves_blocks() {
        let a = Tensor::from_f32(&[2, 1], vec![1., 3.]).unwrap();
        let b = Tensor::from_f32(&[2, 2], vec![10., 11., 30., 31.]).unwrap();
        let y = concat_f32(&[&a, &b], 1).unwrap();
        assert_eq!(y.shape(), &[2, 3]);
        assert_eq!(y.as_f32().unwrap(), &[1., 10., 11., 3., 30., 31.]);
    }

    #[test]
    fn concat_mismatches_rejected() {
        let a = Tensor::zeros(&[1, 2, 2], crate::tf::dtype::DType::F32);
        let b = Tensor::zeros(&[1, 3, 2], crate::tf::dtype::DType::F32);
        assert!(concat_f32(&[&a, &b], 0).is_err(), "non-axis dim mismatch");
        let c = Tensor::zeros(&[2, 2], crate::tf::dtype::DType::F32);
        assert!(concat_f32(&[&a, &c], 0).is_err(), "rank mismatch");
        assert!(concat_f32(&[&a], 3).is_err(), "axis out of range");
        assert!(concat_f32(&[], 0).is_err(), "empty input list");
    }

    #[test]
    fn add_elementwise() {
        let a = Tensor::from_f32(&[3], vec![1., 2., 3.]).unwrap();
        let b = Tensor::from_f32(&[3], vec![10., 20., 30.]).unwrap();
        assert_eq!(add_f32(&a, &b).unwrap().as_f32().unwrap(), &[11., 22., 33.]);
    }

    #[test]
    fn add_shape_mismatch() {
        let a = Tensor::zeros(&[3], crate::tf::dtype::DType::F32);
        let b = Tensor::zeros(&[4], crate::tf::dtype::DType::F32);
        assert!(add_f32(&a, &b).is_err());
    }

    #[test]
    fn bias_broadcasts_last_axis() {
        let x = Tensor::from_f32(&[2, 2], vec![0., 0., 1., 1.]).unwrap();
        let b = Tensor::from_f32(&[2], vec![5., -5.]).unwrap();
        assert_eq!(
            bias_add_f32(&x, &b).unwrap().as_f32().unwrap(),
            &[5., -5., 6., -4.]
        );
    }
}
