//! Elementwise ops.

use crate::hsa::error::{HsaError, Result};
use crate::tf::tensor::Tensor;

/// Elementwise f32 add (shapes must match).
pub fn add_f32(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    if a.shape() != b.shape() {
        return Err(HsaError::KernelFailed(format!(
            "add shape mismatch {:?} vs {:?}",
            a.shape(),
            b.shape()
        )));
    }
    let av = a.as_f32()?;
    let bv = b.as_f32()?;
    let out: Vec<f32> = av.iter().zip(bv).map(|(x, y)| x + y).collect();
    Ok(Tensor::from_f32(a.shape(), out)?)
}

/// `x (.., N) + b (N,)` — broadcast bias over the last axis.
pub fn bias_add_f32(x: &Tensor, b: &Tensor) -> Result<Tensor> {
    let n = *x.shape().last().ok_or_else(|| {
        HsaError::KernelFailed("bias_add on rank-0 tensor".into())
    })?;
    if b.shape() != [n] {
        return Err(HsaError::KernelFailed(format!(
            "bias shape {:?} != [{n}]",
            b.shape()
        )));
    }
    let xd = x.as_f32()?;
    let bd = b.as_f32()?;
    let out: Vec<f32> = xd
        .iter()
        .enumerate()
        .map(|(i, &v)| v + bd[i % n])
        .collect();
    Ok(Tensor::from_f32(x.shape(), out)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_elementwise() {
        let a = Tensor::from_f32(&[3], vec![1., 2., 3.]).unwrap();
        let b = Tensor::from_f32(&[3], vec![10., 20., 30.]).unwrap();
        assert_eq!(add_f32(&a, &b).unwrap().as_f32().unwrap(), &[11., 22., 33.]);
    }

    #[test]
    fn add_shape_mismatch() {
        let a = Tensor::zeros(&[3], crate::tf::dtype::DType::F32);
        let b = Tensor::zeros(&[4], crate::tf::dtype::DType::F32);
        assert!(add_f32(&a, &b).is_err());
    }

    #[test]
    fn bias_broadcasts_last_axis() {
        let x = Tensor::from_f32(&[2, 2], vec![0., 0., 1., 1.]).unwrap();
        let b = Tensor::from_f32(&[2], vec![5., -5.]).unwrap();
        assert_eq!(
            bias_add_f32(&x, &b).unwrap().as_f32().unwrap(),
            &[5., -5., 6., -4.]
        );
    }
}
