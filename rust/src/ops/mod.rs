//! Native Rust kernels: the CPU agent's numerics and the oracle the FPGA
//! path is cross-checked against. Semantics mirror
//! `python/compile/kernels/ref.py` exactly (same accumulation order
//! concerns do not arise: f32 sums are short; int16 paths are exact).

pub mod activation;
pub mod conv2d;
pub mod elementwise;
pub mod matmul;
pub mod pool;
pub mod quant;

pub use activation::{relu_f32, relu_i16, softmax_f32};
pub use conv2d::{
    conv2d_f32, conv2d_f32_relu, conv2d_fixed_f32, conv2d_fixed_f32_relu, conv2d_fixed_i16,
    conv2d_fixed_i16_relu,
};
pub use elementwise::{add_f32, bias_add_f32, concat_f32};
pub use matmul::{fc_f32, fc_relu_f32, matmul_f32};
pub use pool::{global_avgpool_f32, maxpool2_f32};
pub use quant::{dequantize_i16_to_f32, quantize_f32_to_i16};
