//! Dense float32 matmul / fully-connected kernels.

use crate::hsa::error::{HsaError, Result};
use crate::tf::tensor::Tensor;

/// `x (M,K) @ w (K,N) -> (M,N)`, ikj loop order (row-major friendly).
pub fn matmul_f32(x: &Tensor, w: &Tensor) -> Result<Tensor> {
    let (xs, ws) = (x.shape(), w.shape());
    if xs.len() != 2 || ws.len() != 2 || xs[1] != ws[0] {
        return Err(HsaError::KernelFailed(format!(
            "matmul shape mismatch: {xs:?} @ {ws:?}"
        )));
    }
    let (m, k, n) = (xs[0], xs[1], ws[1]);
    let xd = x.as_f32()?;
    let wd = w.as_f32()?;
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let xik = xd[i * k + kk];
            if xik == 0.0 {
                continue;
            }
            let wrow = &wd[kk * n..(kk + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += xik * wrow[j];
            }
        }
    }
    Ok(Tensor::from_f32(&[m, n], out)?)
}

/// Fully connected: `x @ w + b` (roles 1 and 2 — numerically identical;
/// the barrier changes timing, not values).
pub fn fc_f32(x: &Tensor, w: &Tensor, b: &Tensor) -> Result<Tensor> {
    let y = matmul_f32(x, w)?;
    let n = w.shape()[1];
    if b.shape() != [n] {
        return Err(HsaError::KernelFailed(format!(
            "fc bias shape {:?} != [{n}]",
            b.shape()
        )));
    }
    let bd = b.as_f32()?;
    let yd = y.as_f32()?;
    let m = y.shape()[0];
    let mut out = Vec::with_capacity(m * n);
    for i in 0..m {
        for j in 0..n {
            out.push(yd[i * n + j] + bd[j]);
        }
    }
    Ok(Tensor::from_f32(&[m, n], out)?)
}

/// Fused fully connected + ReLU: `max(x @ w + b, 0)` in one kernel call.
/// Defined as `relu_f32 ∘ fc_f32`, so fused and unfused plans are bitwise
/// identical by construction.
pub fn fc_relu_f32(x: &Tensor, w: &Tensor, b: &Tensor) -> Result<Tensor> {
    crate::ops::relu_f32(&fc_f32(x, w, b)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_matmul() {
        let x = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let eye = Tensor::from_f32(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let y = matmul_f32(&x, &eye).unwrap();
        assert_eq!(y.as_f32().unwrap(), x.as_f32().unwrap());
    }

    #[test]
    fn known_product() {
        // [[1,2],[3,4]] @ [[1,1],[1,1]] = [[3,3],[7,7]]
        let x = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let w = Tensor::from_f32(&[2, 2], vec![1.0; 4]).unwrap();
        let y = matmul_f32(&x, &w).unwrap();
        assert_eq!(y.as_f32().unwrap(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn rectangular_shapes() {
        let x = Tensor::from_f32(&[1, 3], vec![1.0, 2.0, 3.0]).unwrap();
        let w = Tensor::from_f32(&[3, 2], vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]).unwrap();
        let y = matmul_f32(&x, &w).unwrap();
        assert_eq!(y.shape(), &[1, 2]);
        assert_eq!(y.as_f32().unwrap(), &[4.0, 5.0]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let x = Tensor::zeros(&[2, 3], crate::tf::dtype::DType::F32);
        let w = Tensor::zeros(&[4, 2], crate::tf::dtype::DType::F32);
        assert!(matmul_f32(&x, &w).is_err());
    }

    #[test]
    fn fc_adds_bias_per_column() {
        let x = Tensor::from_f32(&[2, 2], vec![0.0; 4]).unwrap();
        let w = Tensor::from_f32(&[2, 2], vec![0.0; 4]).unwrap();
        let b = Tensor::from_f32(&[2], vec![1.5, -2.5]).unwrap();
        let y = fc_f32(&x, &w, &b).unwrap();
        assert_eq!(y.as_f32().unwrap(), &[1.5, -2.5, 1.5, -2.5]);
    }

    #[test]
    fn fc_relu_matches_sequential_relu_of_fc() {
        let x = Tensor::from_f32(&[2, 3], vec![1.0, -2.0, 0.5, 0.25, 3.0, -1.5]).unwrap();
        let w = Tensor::from_f32(&[3, 2], vec![0.7, -0.3, 1.1, 0.2, -0.9, 0.4]).unwrap();
        let b = Tensor::from_f32(&[2], vec![-0.1, 0.1]).unwrap();
        let fused = fc_relu_f32(&x, &w, &b).unwrap();
        let seq = crate::ops::relu_f32(&fc_f32(&x, &w, &b).unwrap()).unwrap();
        assert_eq!(fused, seq, "fused FC+ReLU must be bitwise identical");
        assert!(fused.as_f32().unwrap().iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn fc_bad_bias_rejected() {
        let x = Tensor::zeros(&[2, 2], crate::tf::dtype::DType::F32);
        let w = Tensor::zeros(&[2, 2], crate::tf::dtype::DType::F32);
        let b = Tensor::zeros(&[3], crate::tf::dtype::DType::F32);
        assert!(fc_f32(&x, &w, &b).is_err());
    }
}
