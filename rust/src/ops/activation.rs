//! Activation functions.

use crate::hsa::error::Result;
use crate::tf::tensor::Tensor;

pub fn relu_f32(x: &Tensor) -> Result<Tensor> {
    let d = x.as_f32()?;
    let out: Vec<f32> = d.iter().map(|&v| v.max(0.0)).collect();
    Ok(Tensor::from_f32(x.shape(), out)?)
}

pub fn relu_i16(x: &Tensor) -> Result<Tensor> {
    let d = x.as_i16()?;
    let out: Vec<i16> = d.iter().map(|&v| v.max(0)).collect();
    Ok(Tensor::from_i16(x.shape(), out)?)
}

/// Numerically-stable softmax over the last axis of a rank-2 f32 tensor.
pub fn softmax_f32(x: &Tensor) -> Result<Tensor> {
    use crate::hsa::error::HsaError;
    let s = x.shape();
    if s.len() != 2 {
        return Err(HsaError::KernelFailed(format!("softmax rank {} != 2", s.len())));
    }
    let (m, n) = (s[0], s[1]);
    let d = x.as_f32()?;
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        let row = &d[i * n..(i + 1) * n];
        let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        if max == f32::NEG_INFINITY {
            // Every logit is -inf: `v - max` would be NaN for the whole
            // row. All entries are equally (in)finitely unlikely, so the
            // limit distribution is uniform — same as equal finite logits.
            let u = 1.0 / n as f32;
            out[i * n..(i + 1) * n].fill(u);
            continue;
        }
        let mut sum = 0f32;
        for (j, &v) in row.iter().enumerate() {
            let e = (v - max).exp();
            out[i * n + j] = e;
            sum += e;
        }
        for j in 0..n {
            out[i * n + j] /= sum;
        }
    }
    Ok(Tensor::from_f32(s, out)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_f32_clamps_negatives() {
        let x = Tensor::from_f32(&[4], vec![-1.0, 0.0, 2.5, -0.1]).unwrap();
        assert_eq!(relu_f32(&x).unwrap().as_f32().unwrap(), &[0.0, 0.0, 2.5, 0.0]);
    }

    #[test]
    fn relu_i16_clamps_negatives() {
        let x = Tensor::from_i16(&[3], vec![-5, 0, 7]).unwrap();
        assert_eq!(relu_i16(&x).unwrap().as_i16().unwrap(), &[0, 0, 7]);
    }

    #[test]
    fn relu_preserves_shape() {
        let x = Tensor::zeros(&[2, 3, 4], crate::tf::dtype::DType::F32);
        assert_eq!(relu_f32(&x).unwrap().shape(), &[2, 3, 4]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = Tensor::from_f32(&[2, 3], vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]).unwrap();
        let y = softmax_f32(&x).unwrap();
        for row in y.as_f32().unwrap().chunks(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "{row:?}");
            assert!(row.windows(2).all(|w| w[0] < w[1]), "monotone logits");
        }
    }

    #[test]
    fn softmax_stable_for_large_logits() {
        let x = Tensor::from_f32(&[1, 3], vec![1000.0, 1001.0, 1002.0]).unwrap();
        let y = softmax_f32(&x).unwrap();
        assert!(y.as_f32().unwrap().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn softmax_all_neg_inf_row_is_uniform_not_nan() {
        // One all--inf row between two ordinary rows: the degenerate row
        // must come back uniform, and must not contaminate its neighbors.
        let x = Tensor::from_f32(
            &[3, 4],
            vec![
                1.0,
                2.0,
                3.0,
                4.0,
                f32::NEG_INFINITY,
                f32::NEG_INFINITY,
                f32::NEG_INFINITY,
                f32::NEG_INFINITY,
                -1.0,
                0.0,
                1.0,
                2.0,
            ],
        )
        .unwrap();
        let y = softmax_f32(&x).unwrap();
        let rows: Vec<&[f32]> = y.as_f32().unwrap().chunks(4).collect();
        assert!(rows[1].iter().all(|&v| v == 0.25), "degenerate row uniform: {:?}", rows[1]);
        for r in [rows[0], rows[2]] {
            assert!(r.iter().all(|v| v.is_finite()), "neighbor row finite: {r:?}");
            let s: f32 = r.iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_partial_neg_inf_row_stays_well_defined() {
        // -inf logits in an otherwise finite row get probability 0.
        let x = Tensor::from_f32(&[1, 3], vec![f32::NEG_INFINITY, 0.0, 0.0]).unwrap();
        let y = softmax_f32(&x).unwrap();
        let r = y.as_f32().unwrap();
        assert_eq!(r[0], 0.0);
        assert!((r[1] - 0.5).abs() < 1e-6 && (r[2] - 0.5).abs() < 1e-6, "{r:?}");
    }

    #[test]
    fn softmax_uniform_for_equal_logits() {
        let x = Tensor::from_f32(&[1, 4], vec![5.0; 4]).unwrap();
        let y = softmax_f32(&x).unwrap();
        for &v in y.as_f32().unwrap() {
            assert!((v - 0.25).abs() < 1e-6);
        }
    }
}
