//! Fixed-weight valid convolutions (roles 3 and 4), int16 and float32.
//!
//! Same semantics as `python/compile/kernels/ref.py::conv_fixed_ref`:
//! cross-correlation orientation, int32 accumulation for int16 inputs,
//! arithmetic right shift, saturation to int16.

use crate::hsa::error::{HsaError, Result};
use crate::tf::tensor::Tensor;

fn out_dims(
    x: &Tensor,
    f: usize,
    c: usize,
    kh: usize,
    kw: usize,
) -> Result<(usize, usize, usize)> {
    let s = x.shape();
    if s.len() != 3 {
        return Err(HsaError::KernelFailed(format!("conv input rank {} != 3", s.len())));
    }
    if s[0] != c {
        return Err(HsaError::KernelFailed(format!(
            "conv expects {c} channels, got {}",
            s[0]
        )));
    }
    if s[1] < kh || s[2] < kw {
        return Err(HsaError::KernelFailed(format!(
            "input {:?} smaller than filter {kh}x{kw}",
            &s[1..]
        )));
    }
    let _ = f;
    Ok((s[1] - kh + 1, s[2] - kw + 1, s[2]))
}

/// int16 fixed-weight conv: `x (C,H,W) i16`, `weights (F,C,KH,KW) i16`
/// → `(F,OH,OW) i16` with i32 accumulate, `>> shift`, saturate.
pub fn conv2d_fixed_i16(
    x: &Tensor,
    weights: &[i16],
    f: usize,
    c: usize,
    kh: usize,
    kw: usize,
    shift: u32,
) -> Result<Tensor> {
    if weights.len() != f * c * kh * kw {
        return Err(HsaError::KernelFailed("weight length mismatch".into()));
    }
    let (oh, ow, w_dim) = out_dims(x, f, c, kh, kw)?;
    let xd = x.as_i16()?;
    let h = x.shape()[1];
    let _ = h;
    let mut out = vec![0i16; f * oh * ow];
    for fi in 0..f {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc: i32 = 0;
                for ci in 0..c {
                    for a in 0..kh {
                        let xrow = &xd[ci * x.shape()[1] * w_dim + (oy + a) * w_dim + ox..];
                        let wrow = &weights[((fi * c + ci) * kh + a) * kw..];
                        for b in 0..kw {
                            acc += xrow[b] as i32 * wrow[b] as i32;
                        }
                    }
                }
                let v = (acc >> shift).clamp(i16::MIN as i32, i16::MAX as i32);
                out[fi * oh * ow + oy * ow + ox] = v as i16;
            }
        }
    }
    Ok(Tensor::from_i16(&[f, oh, ow], out)?)
}

/// Fused int16 conv + ReLU, enabling single-dispatch fused plan steps.
/// Defined as `relu_i16 ∘ conv2d_fixed_i16`, so it is bitwise identical to
/// the unfused pair by construction.
pub fn conv2d_fixed_i16_relu(
    x: &Tensor,
    weights: &[i16],
    f: usize,
    c: usize,
    kh: usize,
    kw: usize,
    shift: u32,
) -> Result<Tensor> {
    crate::ops::relu_i16(&conv2d_fixed_i16(x, weights, f, c, kh, kw, shift)?)
}

/// float32 fixed-weight conv (the MNIST CNN's layers).
pub fn conv2d_fixed_f32(
    x: &Tensor,
    weights: &[f32],
    f: usize,
    c: usize,
    kh: usize,
    kw: usize,
) -> Result<Tensor> {
    if weights.len() != f * c * kh * kw {
        return Err(HsaError::KernelFailed("weight length mismatch".into()));
    }
    let (oh, ow, w_dim) = out_dims(x, f, c, kh, kw)?;
    let xd = x.as_f32()?;
    let mut out = vec![0f32; f * oh * ow];
    for fi in 0..f {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = 0f32;
                for ci in 0..c {
                    for a in 0..kh {
                        let xbase = ci * x.shape()[1] * w_dim + (oy + a) * w_dim + ox;
                        let wbase = ((fi * c + ci) * kh + a) * kw;
                        for b in 0..kw {
                            acc += xd[xbase + b] * weights[wbase + b];
                        }
                    }
                }
                out[fi * oh * ow + oy * ow + ox] = acc;
            }
        }
    }
    Ok(Tensor::from_f32(&[f, oh, ow], out)?)
}

/// Fused float32 fixed-weight conv + ReLU (`relu_f32 ∘ conv2d_fixed_f32`,
/// bitwise identical to the unfused pair by construction).
pub fn conv2d_fixed_f32_relu(
    x: &Tensor,
    weights: &[f32],
    f: usize,
    c: usize,
    kh: usize,
    kw: usize,
) -> Result<Tensor> {
    crate::ops::relu_f32(&conv2d_fixed_f32(x, weights, f, c, kh, kw)?)
}

/// Generic float32 conv with weights and bias as *tensors* (graph inputs,
/// not baked-in role weights): `x (C,H,W)`, `w (F,C,KH,KW)`, `b (F)`,
/// symmetric zero padding `pad` on both spatial axes, stride 1 —
/// `(F, H+2p-KH+1, W+2p-KW+1)`. This is the landing op for imported ONNX
/// `Conv` nodes, whose weights arrive as graph constants rather than
/// pre-registered WeightBank entries.
pub fn conv2d_f32(x: &Tensor, w: &Tensor, b: &Tensor, pad: usize) -> Result<Tensor> {
    let xs = x.shape();
    let ws = w.shape();
    let bs = b.shape();
    if xs.len() != 3 {
        return Err(HsaError::KernelFailed(format!("conv2d input rank {} != 3", xs.len())));
    }
    if ws.len() != 4 {
        return Err(HsaError::KernelFailed(format!("conv2d weight rank {} != 4", ws.len())));
    }
    let (c, h, wi) = (xs[0], xs[1], xs[2]);
    let (f, wc, kh, kw) = (ws[0], ws[1], ws[2], ws[3]);
    if wc != c {
        return Err(HsaError::KernelFailed(format!(
            "conv2d weight expects {wc} channels, input has {c}"
        )));
    }
    if bs != [f] {
        return Err(HsaError::KernelFailed(format!(
            "conv2d bias shape {bs:?} != [{f}]"
        )));
    }
    if h + 2 * pad < kh || wi + 2 * pad < kw {
        return Err(HsaError::KernelFailed(format!(
            "padded input {}x{} smaller than filter {kh}x{kw}",
            h + 2 * pad,
            wi + 2 * pad
        )));
    }
    let (oh, ow) = (h + 2 * pad - kh + 1, wi + 2 * pad - kw + 1);
    let xd = x.as_f32()?;
    let wd = w.as_f32()?;
    let bd = b.as_f32()?;
    let mut out = vec![0f32; f * oh * ow];
    for fi in 0..f {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = bd[fi];
                for ci in 0..c {
                    for a in 0..kh {
                        // Input row oy + a - pad; skip rows in the zero border.
                        let iy = (oy + a).wrapping_sub(pad);
                        if iy >= h {
                            continue;
                        }
                        let wbase = ((fi * c + ci) * kh + a) * kw;
                        for bk in 0..kw {
                            let ix = (ox + bk).wrapping_sub(pad);
                            if ix >= wi {
                                continue;
                            }
                            acc += xd[ci * h * wi + iy * wi + ix] * wd[wbase + bk];
                        }
                    }
                }
                out[fi * oh * ow + oy * ow + ox] = acc;
            }
        }
    }
    Ok(Tensor::from_f32(&[f, oh, ow], out)?)
}

/// Fused generic conv + ReLU (`relu_f32 ∘ conv2d_f32`, bitwise identical
/// to the unfused pair by construction).
pub fn conv2d_f32_relu(x: &Tensor, w: &Tensor, b: &Tensor, pad: usize) -> Result<Tensor> {
    crate::ops::relu_f32(&conv2d_f32(x, w, b, pad)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_tap_i16() {
        // 1x1 filter with weight 1<<shift reproduces the input.
        let x = Tensor::from_i16(&[1, 3, 3], (1..=9).collect()).unwrap();
        let w = vec![1i16 << 4];
        let y = conv2d_fixed_i16(&x, &w, 1, 1, 1, 1, 4).unwrap();
        assert_eq!(y.as_i16().unwrap(), x.as_i16().unwrap());
    }

    #[test]
    fn box_filter_i16() {
        // 2x2 all-ones over a constant image: each output = 4*v >> 0.
        let x = Tensor::from_i16(&[1, 3, 3], vec![3; 9]).unwrap();
        let w = vec![1i16; 4];
        let y = conv2d_fixed_i16(&x, &w, 1, 1, 2, 2, 0).unwrap();
        assert_eq!(y.shape(), &[1, 2, 2]);
        assert!(y.as_i16().unwrap().iter().all(|&v| v == 12));
    }

    #[test]
    fn saturation_clamps() {
        let x = Tensor::from_i16(&[1, 2, 2], vec![32000; 4]).unwrap();
        let w = vec![127i16; 4];
        let y = conv2d_fixed_i16(&x, &w, 1, 1, 2, 2, 0).unwrap();
        assert_eq!(y.as_i16().unwrap(), &[32767]);
        let xn = Tensor::from_i16(&[1, 2, 2], vec![-32000; 4]).unwrap();
        let yn = conv2d_fixed_i16(&xn, &w, 1, 1, 2, 2, 0).unwrap();
        assert_eq!(yn.as_i16().unwrap(), &[-32768]);
    }

    #[test]
    fn multi_filter_multi_channel_f32() {
        // 2 channels, 2 filters of 1x1: filter0 = ch0 + ch1, filter1 = ch0 - ch1.
        let x = Tensor::from_f32(&[2, 2, 2], vec![1., 2., 3., 4., 10., 20., 30., 40.])
            .unwrap();
        let w = vec![1., 1., 1., -1.];
        let y = conv2d_fixed_f32(&x, &w, 2, 2, 1, 1).unwrap();
        assert_eq!(y.shape(), &[2, 2, 2]);
        assert_eq!(&y.as_f32().unwrap()[..4], &[11., 22., 33., 44.]);
        assert_eq!(&y.as_f32().unwrap()[4..], &[-9., -18., -27., -36.]);
    }

    #[test]
    fn arithmetic_shift_preserves_sign() {
        let x = Tensor::from_i16(&[1, 1, 1], vec![-100]).unwrap();
        let w = vec![1i16];
        let y = conv2d_fixed_i16(&x, &w, 1, 1, 1, 1, 2).unwrap();
        // -100 >> 2 (arithmetic) = -25.
        assert_eq!(y.as_i16().unwrap(), &[-25]);
    }

    #[test]
    fn fused_conv_relu_matches_sequential() {
        let x = Tensor::from_i16(&[1, 4, 4], (0..16).map(|v| v as i16 - 8).collect())
            .unwrap();
        let w = vec![3i16, -2, 1, -1];
        let fused = conv2d_fixed_i16_relu(&x, &w, 1, 1, 2, 2, 1).unwrap();
        let seq = crate::ops::relu_i16(&conv2d_fixed_i16(&x, &w, 1, 1, 2, 2, 1).unwrap())
            .unwrap();
        assert_eq!(fused, seq);

        let xf = Tensor::from_f32(&[1, 3, 3], (0..9).map(|v| v as f32 - 4.0).collect())
            .unwrap();
        let wf = vec![1.0f32, -1.0, -1.0, 1.0];
        let fusedf = conv2d_fixed_f32_relu(&xf, &wf, 1, 1, 2, 2).unwrap();
        let seqf = crate::ops::relu_f32(&conv2d_fixed_f32(&xf, &wf, 1, 1, 2, 2).unwrap())
            .unwrap();
        assert_eq!(fusedf, seqf);
    }

    #[test]
    fn conv2d_f32_matches_fixed_conv_when_unpadded_zero_bias() {
        let x = Tensor::from_f32(&[2, 4, 4], (0..32).map(|v| v as f32 * 0.5 - 3.0).collect())
            .unwrap();
        let wdata: Vec<f32> = (0..2 * 2 * 3 * 3).map(|v| (v as f32 - 8.0) * 0.25).collect();
        let w = Tensor::from_f32(&[2, 2, 3, 3], wdata.clone()).unwrap();
        let b = Tensor::from_f32(&[2], vec![0.0, 0.0]).unwrap();
        let y = conv2d_f32(&x, &w, &b, 0).unwrap();
        let want = conv2d_fixed_f32(&x, &wdata, 2, 2, 3, 3).unwrap();
        assert_eq!(y.shape(), want.shape());
        for (a, g) in want.as_f32().unwrap().iter().zip(y.as_f32().unwrap()) {
            assert!((a - g).abs() < 1e-5, "{a} vs {g}");
        }
    }

    #[test]
    fn conv2d_f32_same_padding_keeps_spatial_dims() {
        // 3x3 filter, pad 1: output spatial dims equal input's. A 1x1
        // all-ones filter with pad 0 plus bias checks the bias add.
        let x = Tensor::from_f32(&[1, 3, 3], (1..=9).map(|v| v as f32).collect()).unwrap();
        let w = Tensor::from_f32(&[1, 1, 3, 3], vec![0., 0., 0., 0., 1., 0., 0., 0., 0.])
            .unwrap();
        let b = Tensor::from_f32(&[1], vec![10.0]).unwrap();
        let y = conv2d_f32(&x, &w, &b, 1).unwrap();
        assert_eq!(y.shape(), &[1, 3, 3]);
        // Center-tap identity + bias: y = x + 10, padding contributed zeros.
        let got = y.as_f32().unwrap();
        for (i, &v) in got.iter().enumerate() {
            assert_eq!(v, (i + 1) as f32 + 10.0);
        }
    }

    #[test]
    fn conv2d_f32_padding_border_sums() {
        // 2x2 all-ones filter over a 2x2 ones image with pad 1: corner
        // outputs see 1 input cell, edges 2, center 4.
        let x = Tensor::from_f32(&[1, 2, 2], vec![1.0; 4]).unwrap();
        let w = Tensor::from_f32(&[1, 1, 2, 2], vec![1.0; 4]).unwrap();
        let b = Tensor::from_f32(&[1], vec![0.0]).unwrap();
        let y = conv2d_f32(&x, &w, &b, 1).unwrap();
        assert_eq!(y.shape(), &[1, 3, 3]);
        assert_eq!(y.as_f32().unwrap(), &[1., 2., 1., 2., 4., 2., 1., 2., 1.]);
    }

    #[test]
    fn conv2d_f32_fused_relu_matches_sequential() {
        let x = Tensor::from_f32(&[1, 3, 3], (0..9).map(|v| v as f32 - 4.0).collect())
            .unwrap();
        let w = Tensor::from_f32(&[1, 1, 2, 2], vec![1.0, -1.0, -1.0, 1.0]).unwrap();
        let b = Tensor::from_f32(&[1], vec![-0.5]).unwrap();
        let fused = conv2d_f32_relu(&x, &w, &b, 1).unwrap();
        let seq = crate::ops::relu_f32(&conv2d_f32(&x, &w, &b, 1).unwrap()).unwrap();
        assert_eq!(fused, seq);
    }

    #[test]
    fn conv2d_f32_shape_mismatches_rejected() {
        let x = Tensor::zeros(&[2, 4, 4], crate::tf::dtype::DType::F32);
        let w = Tensor::zeros(&[1, 3, 3, 3], crate::tf::dtype::DType::F32);
        let b = Tensor::zeros(&[1], crate::tf::dtype::DType::F32);
        assert!(conv2d_f32(&x, &w, &b, 0).is_err(), "channel mismatch");
        let w = Tensor::zeros(&[1, 2, 3, 3], crate::tf::dtype::DType::F32);
        let b2 = Tensor::zeros(&[2], crate::tf::dtype::DType::F32);
        assert!(conv2d_f32(&x, &w, &b2, 0).is_err(), "bias length mismatch");
        let tiny = Tensor::zeros(&[2, 2, 2], crate::tf::dtype::DType::F32);
        assert!(conv2d_f32(&tiny, &w, &b, 0).is_err(), "input smaller than filter");
    }

    #[test]
    fn wrong_channel_count_rejected() {
        let x = Tensor::zeros(&[2, 4, 4], crate::tf::dtype::DType::I16);
        assert!(conv2d_fixed_i16(&x, &[0; 9], 1, 1, 3, 3, 0).is_err());
    }

    #[test]
    fn too_small_input_rejected() {
        let x = Tensor::zeros(&[1, 2, 2], crate::tf::dtype::DType::I16);
        assert!(conv2d_fixed_i16(&x, &[0; 9], 1, 1, 3, 3, 0).is_err());
    }
}
