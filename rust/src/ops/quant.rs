//! Fixed-point conversion between the f32 frontend and the int16 conv roles.
//!
//! The int16 roles use a Qm.n-style scale: `q = round(x * 2^frac_bits)`,
//! saturated; dequantization divides back. `frac_bits` pairs with the conv
//! roles' accumulator shift.

use crate::hsa::error::{HsaError, Result};
use crate::tf::tensor::Tensor;

/// Quantize, saturating at the i16 range. Non-finite inputs are rejected
/// with a named error: NaN would otherwise slip through `clamp` (which
/// propagates NaN) and be silently zeroed by the saturating `as i16` cast,
/// turning a poisoned activation into a confident mid-scale value.
pub fn quantize_f32_to_i16(x: &Tensor, frac_bits: u32) -> Result<Tensor> {
    let scale = (1i64 << frac_bits) as f32;
    let d = x.as_f32()?;
    let mut out = Vec::with_capacity(d.len());
    for (i, &v) in d.iter().enumerate() {
        if !v.is_finite() {
            return Err(HsaError::KernelFailed(format!(
                "quantize: non-finite input {v} at index {i} (frac_bits {frac_bits}); \
                 quantization requires finite f32 values"
            )));
        }
        out.push(
            (v * scale)
                .round()
                .clamp(i16::MIN as f32, i16::MAX as f32) as i16,
        );
    }
    Ok(Tensor::from_i16(x.shape(), out)?)
}

pub fn dequantize_i16_to_f32(x: &Tensor, frac_bits: u32) -> Result<Tensor> {
    let scale = (1i64 << frac_bits) as f32;
    let d = x.as_i16()?;
    let out: Vec<f32> = d.iter().map(|&v| v as f32 / scale).collect();
    Ok(Tensor::from_f32(x.shape(), out)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_small_values() {
        let x = Tensor::from_f32(&[4], vec![0.5, -0.25, 1.0, 0.0]).unwrap();
        let q = quantize_f32_to_i16(&x, 8).unwrap();
        let d = dequantize_i16_to_f32(&q, 8).unwrap();
        for (a, b) in x.as_f32().unwrap().iter().zip(d.as_f32().unwrap()) {
            assert!((a - b).abs() < 1.0 / 256.0, "{a} vs {b}");
        }
    }

    #[test]
    fn saturates_out_of_range() {
        let x = Tensor::from_f32(&[2], vec![1e6, -1e6]).unwrap();
        let q = quantize_f32_to_i16(&x, 8).unwrap();
        assert_eq!(q.as_i16().unwrap(), &[32767, -32768]);
    }

    #[test]
    fn non_finite_inputs_are_rejected_not_zeroed() {
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let x = Tensor::from_f32(&[3], vec![0.5, bad, 0.25]).unwrap();
            let err = quantize_f32_to_i16(&x, 8).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains("non-finite"), "{msg}");
            assert!(msg.contains("index 1"), "names the offending index: {msg}");
        }
    }

    #[test]
    fn quantization_is_rounding_not_truncating() {
        // 2.5/256 is exact in binary: quantizes to 2.5, rounds away to 3.
        let x = Tensor::from_f32(&[1], vec![2.5 / 256.0]).unwrap();
        let q = quantize_f32_to_i16(&x, 8).unwrap();
        assert_eq!(q.as_i16().unwrap(), &[3]);
    }
}
