//! Pooling ops.

use crate::hsa::error::{HsaError, Result};
use crate::tf::tensor::Tensor;

/// 2×2 max pool, stride 2, over `(C,H,W)` f32; trailing odd row/col dropped
/// (matches `ref.py::maxpool2_ref`).
pub fn maxpool2_f32(x: &Tensor) -> Result<Tensor> {
    let s = x.shape();
    if s.len() != 3 {
        return Err(HsaError::KernelFailed(format!("maxpool rank {} != 3", s.len())));
    }
    let (c, h, w) = (s[0], s[1], s[2]);
    let (h2, w2) = (h / 2, w / 2);
    let d = x.as_f32()?;
    let mut out = vec![0f32; c * h2 * w2];
    for ci in 0..c {
        for y in 0..h2 {
            for xx in 0..w2 {
                let base = ci * h * w + 2 * y * w + 2 * xx;
                let m = d[base]
                    .max(d[base + 1])
                    .max(d[base + w])
                    .max(d[base + w + 1]);
                out[ci * h2 * w2 + y * w2 + xx] = m;
            }
        }
    }
    Ok(Tensor::from_f32(&[c, h2, w2], out)?)
}

/// Global average pool over `(C,H,W)` f32 → `(C,1,1)` (ONNX
/// `GlobalAveragePool` semantics, keeping the spatial rank). Each channel
/// averages in row-major order, so the reduction is deterministic.
pub fn global_avgpool_f32(x: &Tensor) -> Result<Tensor> {
    let s = x.shape();
    if s.len() != 3 {
        return Err(HsaError::KernelFailed(format!(
            "global_avgpool rank {} != 3",
            s.len()
        )));
    }
    let (c, h, w) = (s[0], s[1], s[2]);
    if h * w == 0 {
        return Err(HsaError::KernelFailed("global_avgpool over empty spatial dims".into()));
    }
    let d = x.as_f32()?;
    let mut out = vec![0f32; c];
    let inv = 1.0 / (h * w) as f32;
    for ci in 0..c {
        let mut sum = 0f32;
        for &v in &d[ci * h * w..(ci + 1) * h * w] {
            sum += v;
        }
        out[ci] = sum * inv;
    }
    Ok(Tensor::from_f32(&[c, 1, 1], out)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_avgpool_averages_each_channel() {
        let x = Tensor::from_f32(&[2, 2, 2], vec![1., 2., 3., 4., 10., 20., 30., 40.])
            .unwrap();
        let y = global_avgpool_f32(&x).unwrap();
        assert_eq!(y.shape(), &[2, 1, 1]);
        assert_eq!(y.as_f32().unwrap(), &[2.5, 25.0]);
    }

    #[test]
    fn global_avgpool_wrong_rank_rejected() {
        let x = Tensor::zeros(&[4, 4], crate::tf::dtype::DType::F32);
        assert!(global_avgpool_f32(&x).is_err());
    }

    #[test]
    fn pools_max_of_each_window() {
        let x = Tensor::from_f32(
            &[1, 2, 4],
            vec![1., 5., 2., 0., 3., 4., 1., 9.],
        )
        .unwrap();
        let y = maxpool2_f32(&x).unwrap();
        assert_eq!(y.shape(), &[1, 1, 2]);
        assert_eq!(y.as_f32().unwrap(), &[5., 9.]);
    }

    #[test]
    fn odd_dims_drop_trailing() {
        let x = Tensor::from_f32(&[1, 3, 3], (0..9).map(|v| v as f32).collect()).unwrap();
        let y = maxpool2_f32(&x).unwrap();
        assert_eq!(y.shape(), &[1, 1, 1]);
        assert_eq!(y.as_f32().unwrap(), &[4.0]); // max of [[0,1],[3,4]]
    }

    #[test]
    fn multi_channel_independent() {
        let x = Tensor::from_f32(&[2, 2, 2], vec![1., 2., 3., 4., 8., 7., 6., 5.]).unwrap();
        let y = maxpool2_f32(&x).unwrap();
        assert_eq!(y.as_f32().unwrap(), &[4.0, 8.0]);
    }

    #[test]
    fn wrong_rank_rejected() {
        let x = Tensor::zeros(&[4, 4], crate::tf::dtype::DType::F32);
        assert!(maxpool2_f32(&x).is_err());
    }
}
