"""AOT driver: lower every L2 entry point to HLO *text* + write a manifest.

HLO text (not ``HloModuleProto.serialize()``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the Rust ``xla`` crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts
Outputs:
    artifacts/<name>.hlo.txt     one per entry point
    artifacts/weights/<name>.bin raw little-endian weight blobs (for the
                                 Rust native CPU baseline)
    artifacts/manifest.json      shapes/dtypes/files, read by rust runtime
"""

import argparse
import json
import os

import numpy as np
import jax
from jax._src.lib import xla_client as xc

from . import model

_DTYPES = {
    "f32": np.float32,
    "i16": np.int16,
    "i32": np.int32,
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is essential: the default elides big dense
    # constants as `constant({...})`, which the 0.5.1 text parser silently
    # reads back as zeros — fixed weights baked into a model would vanish.
    return comp.as_hlo_text(print_large_constants=True)


def lower_entry(name: str):
    spec = model.ROLE_SHAPES[name]
    fn = model.ENTRY_POINTS[name]
    args = [
        jax.ShapeDtypeStruct(shape, _DTYPES[dt]) for _, shape, dt in spec["inputs"]
    ]
    return jax.jit(fn).lower(*args)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", nargs="*", default=None, help="subset of entry points"
    )
    ns = ap.parse_args()
    os.makedirs(ns.out_dir, exist_ok=True)
    wdir = os.path.join(ns.out_dir, "weights")
    os.makedirs(wdir, exist_ok=True)

    names = ns.only or list(model.ENTRY_POINTS)
    manifest = {"version": 1, "seed": model.SEED, "modules": {}, "weights": {}}

    for name in names:
        spec = model.ROLE_SHAPES[name]
        hlo = to_hlo_text(lower_entry(name))
        fname = f"{name}.hlo.txt"
        with open(os.path.join(ns.out_dir, fname), "w") as f:
            f.write(hlo)
        out_shape, out_dt = spec["output"]
        manifest["modules"][name] = {
            "file": fname,
            "inputs": [
                {"name": n, "shape": list(s), "dtype": d}
                for n, s, d in spec["inputs"]
            ],
            "output": {"shape": list(out_shape), "dtype": out_dt},
            # return_tuple=True => rust must unwrap a 1-tuple
            "tuple_output": True,
        }
        print(f"lowered {name:18s} -> {fname} ({len(hlo)} chars)")

    for key, arr in model.role_weights().items():
        fname = key.replace("/", "_") + ".bin"
        arr.tofile(os.path.join(wdir, fname))
        manifest["weights"][key] = {
            "file": f"weights/{fname}",
            "shape": list(arr.shape),
            "dtype": {"float32": "f32", "int16": "i16"}[str(arr.dtype)],
        }

    manifest["conv_shift"] = model.CONV_SHIFT
    with open(os.path.join(ns.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote manifest with {len(manifest['modules'])} modules")


if __name__ == "__main__":
    main()
