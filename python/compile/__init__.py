"""Build-time compile package: L2 jax model + L1 Pallas kernels + AOT driver.

Nothing in here runs on the request path; ``make artifacts`` invokes
``python -m compile.aot`` once and the Rust binary consumes the HLO text
files it produces.
"""
