"""Layer-2 jax model: the four paper roles as entry points + an MNIST CNN.

Each entry point is a plain jax function built on the L1 Pallas kernels;
``aot.py`` lowers them to HLO text that the Rust runtime loads via PJRT.

Weights are *fixed* (paper: "fix layer weights to have more efficient
hardware"): generated from a deterministic seed, baked into the HLO as
constants, and also exported as raw binaries so the Rust CPU baseline can
run the identical network natively.
"""

import numpy as np
import jax
import jax.numpy as jnp

from .kernels import fc, fc_barrier, conv_fixed_i16, conv_fixed_f32
from .kernels import ref

SEED = 0x5EED_1027  # project number 16ES1027, per the paper's acknowledgment

# ---------------------------------------------------------------------------
# Deterministic fixed weights
# ---------------------------------------------------------------------------


def _rng(tag: str) -> np.random.Generator:
    return np.random.default_rng([SEED, abs(hash(tag)) % (2**31)])


def _rng_stable(tag: str) -> np.random.Generator:
    # hash() is salted per-process for str; use a stable digest instead.
    import zlib

    return np.random.default_rng([SEED, zlib.crc32(tag.encode())])


def role_weights():
    """All fixed weights, keyed by name (numpy arrays, deterministic)."""
    w = {}
    g = _rng_stable("role1_fc")
    w["role1/w"] = g.normal(0, 0.1, (64, 64)).astype(np.float32)
    w["role1/b"] = g.normal(0, 0.1, (64,)).astype(np.float32)
    g = _rng_stable("role2_fc_barrier")
    w["role2/w"] = g.normal(0, 0.1, (64, 64)).astype(np.float32)
    w["role2/b"] = g.normal(0, 0.1, (64,)).astype(np.float32)
    g = _rng_stable("role3_conv5x5")
    w["role3/w"] = g.integers(-128, 128, (1, 1, 5, 5)).astype(np.int16)
    g = _rng_stable("role4_conv3x3")
    w["role4/w"] = g.integers(-128, 128, (2, 1, 3, 3)).astype(np.int16)
    # MNIST CNN (f32): conv3x3 x2f -> pool -> conv5x5 2c->4f -> pool -> fc -> fc
    g = _rng_stable("mnist_cnn")
    w["cnn/conv1"] = g.normal(0, 0.2, (2, 1, 3, 3)).astype(np.float32)
    w["cnn/conv2"] = g.normal(0, 0.15, (4, 2, 5, 5)).astype(np.float32)
    w["cnn/fc1_w"] = g.normal(0, 0.1, (64, 32)).astype(np.float32)
    w["cnn/fc1_b"] = np.zeros(32, np.float32)
    w["cnn/fc2_w"] = g.normal(0, 0.1, (32, 10)).astype(np.float32)
    w["cnn/fc2_b"] = np.zeros(10, np.float32)
    return w


_W = role_weights()

# Paper role workload shapes (see DESIGN.md §6): FC is 64x64x64; the conv
# roles process a 28x28 feature map — the MNIST-scale workload the paper's
# mobile use case targets.
ROLE_SHAPES = {
    # Roles 1/2 are *generic* FC datapaths (weights streamed at run time;
    # the paper marks only the conv roles as weight-fixed).
    "role1_fc": dict(
        inputs=[
            ("x", (64, 64), "f32"),
            ("w", (64, 64), "f32"),
            ("b", (64,), "f32"),
        ],
        output=((64, 64), "f32"),
    ),
    "role2_fc_barrier": dict(
        inputs=[
            ("x", (64, 64), "f32"),
            ("w", (64, 64), "f32"),
            ("b", (64,), "f32"),
        ],
        output=((64, 64), "f32"),
    ),
    "role3_conv5x5": dict(
        inputs=[("x", (1, 28, 28), "i16")], output=((1, 24, 24), "i16")
    ),
    "role4_conv3x3": dict(
        inputs=[("x", (1, 28, 28), "i16")], output=((2, 26, 26), "i16")
    ),
    "mnist_cnn": dict(
        inputs=[("x", (32, 1, 28, 28), "f32")], output=((32, 10), "f32")
    ),
}

CONV_SHIFT = 8  # fixed-point rescale of the int16 conv accumulator

# ---------------------------------------------------------------------------
# Role entry points (what gets AOT-lowered; weights are baked constants)
# ---------------------------------------------------------------------------


def role1_fc(x, w, b):
    """Role 1: generic FC float32. x (64,64), w (64,64), b (64,) -> (64,64)."""
    return fc(x, w, b)


def role2_fc_barrier(x, w, b):
    """Role 2: FC float32 with barrier-synchronized datapath (same math)."""
    return fc_barrier(x, w, b)


_conv3 = None
_conv5 = None


def _convs():
    global _conv3, _conv5
    if _conv3 is None:
        _conv5 = conv_fixed_i16(_W["role3/w"], shift=CONV_SHIFT)
        _conv3 = conv_fixed_i16(_W["role4/w"], shift=CONV_SHIFT)
    return _conv3, _conv5


def role3_conv5x5(x):
    """Role 3: conv 5x5, 1 filter, fixed weights, int16. (1,28,28)->(1,24,24)."""
    _, c5 = _convs()
    return c5(x)


def role4_conv3x3(x):
    """Role 4: conv 3x3, 2 filters, fixed weights, int16. (1,28,28)->(2,26,26)."""
    c3, _ = _convs()
    return c3(x)


# ---------------------------------------------------------------------------
# MNIST-style CNN (the end-to-end workload): all compute via Pallas kernels
# ---------------------------------------------------------------------------


def _cnn_single(x):
    """x (1,28,28) f32 -> logits (10,) f32."""
    conv1 = conv_fixed_f32(_W["cnn/conv1"])  # (2,26,26)
    conv2 = conv_fixed_f32(_W["cnn/conv2"])  # (4,9,9)
    h = conv1(x)
    h = ref.relu_ref(h)
    h = ref.maxpool2_ref(h)  # (2,13,13)
    h = conv2(h)
    h = ref.relu_ref(h)
    h = ref.maxpool2_ref(h)  # (4,4,4)
    h = h.reshape(1, 64)
    h = fc(h, jnp.asarray(_W["cnn/fc1_w"]), jnp.asarray(_W["cnn/fc1_b"]))
    h = ref.relu_ref(h)
    h = fc(h, jnp.asarray(_W["cnn/fc2_w"]), jnp.asarray(_W["cnn/fc2_b"]))
    return h[0]


def mnist_cnn(x):
    """Batched CNN inference. x (B,1,28,28) f32 -> (B,10) f32 logits."""
    return jax.vmap(_cnn_single)(x)


# Reference (pure-jnp, no Pallas) for the full CNN — the L2-level oracle.


def _cnn_single_ref(x):
    h = ref.conv_f32_ref(x, _W["cnn/conv1"])
    h = ref.maxpool2_ref(ref.relu_ref(h))
    h = ref.conv_f32_ref(h, _W["cnn/conv2"])
    h = ref.maxpool2_ref(ref.relu_ref(h))
    h = h.reshape(1, 64)
    h = ref.fc_ref(h, _W["cnn/fc1_w"], _W["cnn/fc1_b"])
    h = ref.relu_ref(h)
    h = ref.fc_ref(h, _W["cnn/fc2_w"], _W["cnn/fc2_b"])
    return h[0]


def mnist_cnn_ref(x):
    return jax.vmap(_cnn_single_ref)(x)


ENTRY_POINTS = {
    "role1_fc": role1_fc,
    "role2_fc_barrier": role2_fc_barrier,
    "role3_conv5x5": role3_conv5x5,
    "role4_conv3x3": role4_conv3x3,
    "mnist_cnn": mnist_cnn,
}

# ---------------------------------------------------------------------------
# Model-bundle export: the Rust runtime's `tf::model` serving format.
#
# A bundle is a directory holding `model.json`: a GraphDef (nodes with op
# tags mirroring the Rust `OpKind` variants), named signatures (endpoint
# name -> node, shape, dtype), and the list of weight-artifact names the
# graph references. Weights are either *embedded* as constant nodes
# (json floats round-trip f32 exactly: np.float32 -> python float widens
# losslessly and json prints the shortest f64 form) or *referenced* by
# artifact name and resolved by the Rust session's weight bank. This is
# the piece that closes the Python -> FPGA loop: build here, serve with
# `tf-fpga serve --model <dir>` — no specialized toolchain in between.
# ---------------------------------------------------------------------------

BUNDLE_FORMAT = "tf-fpga-model-bundle"
BUNDLE_VERSION = 1


def _node(name, op, inputs=None, device=None, **fields):
    n = {"name": name, "op": op}
    if inputs:
        n["inputs"] = list(inputs)
    if device:
        n["device"] = device
    n.update(fields)
    return n


def _constant(name, array):
    arr = np.asarray(array)
    dtype = {"float32": "f32", "int16": "i16", "int32": "i32"}[str(arr.dtype)]
    data = [
        float(v) if dtype == "f32" else int(v) for v in arr.reshape(-1)
    ]
    return _node(
        name,
        "constant",
        tensor={"shape": list(arr.shape), "dtype": dtype, "data": data},
    )


def _endpoint(name, node, shape, dtype="f32"):
    return {"name": name, "node": node, "shape": list(shape), "dtype": dtype}


def _bundle_doc(name, nodes, signatures):
    artifacts = set()
    for n in nodes:
        if n["op"] == "conv_fixed_f32":
            artifacts.add(n["weights"])
        elif n["op"] == "fc_fixed":
            artifacts.add(n["weights_w"])
            artifacts.add(n["weights_b"])
    return {
        "format": BUNDLE_FORMAT,
        "version": BUNDLE_VERSION,
        "name": name,
        "graph": {"nodes": nodes},
        "signatures": signatures,
        "artifacts": sorted(artifacts),
    }


def write_bundle(doc, out_dir):
    import json
    import os

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "model.json")
    # allow_nan=False: JSON has no NaN/Infinity and the Rust parser
    # rejects the bare tokens — fail loudly here, at the source, instead
    # of exporting a bundle that can never load. Serialize fully before
    # touching the file so a failure never truncates an existing bundle.
    text = json.dumps(doc, indent=2, sort_keys=True, allow_nan=False)
    with open(path, "w") as f:
        f.write(text)
    return path


def mnist_cnn_bundle(max_batch=32):
    """Whole-model CNN (one `mnist_cnn` dispatch per batch), batched
    generically along dim 0 — the canonical servable export."""
    nodes = [
        _node("x", "placeholder", shape=[max_batch, 1, 28, 28], dtype="f32"),
        _node("logits", "mnist_cnn", inputs=["x"], device="fpga"),
    ]
    sig = {
        "name": "serve",
        "inputs": [_endpoint("x", "x", [max_batch, 1, 28, 28])],
        "outputs": [_endpoint("logits", "logits", [max_batch, 10])],
    }
    return _bundle_doc("mnist", nodes, [sig])


def mnist_layers_bundle():
    """The CNN as per-layer ops with *named weight-artifact references*
    (`cnn/conv1`, `cnn/fc1_w`, ...) resolved by the Rust weight bank."""
    nodes = [
        _node("x", "placeholder", shape=[1, 28, 28], dtype="f32"),
        _node("conv1", "conv_fixed_f32", inputs=["x"],
              weights="cnn/conv1", filters=2, cin=1, kh=3, kw=3),
        _node("relu1", "relu", inputs=["conv1"]),
        _node("pool1", "maxpool2", inputs=["relu1"]),
        _node("conv2", "conv_fixed_f32", inputs=["pool1"],
              weights="cnn/conv2", filters=4, cin=2, kh=5, kw=5),
        _node("relu2", "relu", inputs=["conv2"]),
        _node("pool2", "maxpool2", inputs=["relu2"]),
        _node("flat", "reshape", inputs=["pool2"], shape=[1, 64]),
        _node("fc1", "fc_fixed", inputs=["flat"],
              weights_w="cnn/fc1_w", weights_b="cnn/fc1_b", out_width=32),
        _node("relu3", "relu", inputs=["fc1"]),
        _node("logits", "fc_fixed", inputs=["relu3"],
              weights_w="cnn/fc2_w", weights_b="cnn/fc2_b", out_width=10),
    ]
    sig = {
        "name": "serve",
        "inputs": [_endpoint("x", "x", [1, 28, 28])],
        "outputs": [_endpoint("logits", "logits", [1, 10])],
    }
    return _bundle_doc("mnist_layers", nodes, [sig])


def tiny_fc_weights(in_dim=16, out_dim=4):
    g = _rng_stable("tiny_fc")
    w = g.normal(0, 0.3, (in_dim, out_dim)).astype(np.float32)
    b = g.normal(0, 0.1, (out_dim,)).astype(np.float32)
    return w, b


def tiny_fc_bundle(batch=8, in_dim=16, out_dim=4):
    """A dense model with weights *embedded* in the GraphDef — fully
    self-contained, and an input shape unlike MNIST's, proving the serving
    stack carries arbitrary leading-batch-dim shapes."""
    w, b = tiny_fc_weights(in_dim, out_dim)
    nodes = [
        _node("x", "placeholder", shape=[batch, in_dim], dtype="f32"),
        _constant("w", w),
        _constant("b", b),
        _node("fc", "fully_connected", inputs=["x", "w", "b"], device="fpga"),
        _node("y", "relu", inputs=["fc"]),
    ]
    sig = {
        "name": "serve",
        "inputs": [_endpoint("x", "x", [batch, in_dim])],
        "outputs": [_endpoint("y", "y", [batch, out_dim])],
    }
    return _bundle_doc("tiny_fc", nodes, [sig])


def export(out_dir, max_batch=32):
    """Export every demo bundle under `out_dir/<name>/model.json`."""
    import os

    paths = []
    for doc in [
        mnist_cnn_bundle(max_batch),
        mnist_layers_bundle(),
        tiny_fc_bundle(),
    ]:
        paths.append(write_bundle(doc, os.path.join(out_dir, doc["name"])))
    return paths
