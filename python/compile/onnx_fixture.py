"""Deterministic ONNX fixture generator for the Rust importer tests.

Builds small TinyML-class ONNX models (a ResNet-8-style classifier plus two
tiny coverage models) *without* the ``onnx`` package: the protobuf wire
format is hand-encoded here, mirroring the hand-rolled decoder in
``rust/src/tf/onnx.rs``. Alongside each ``.onnx`` file an
``.expected.json`` golden records a deterministic input and the float32
logits computed by a NumPy reference forward pass (BatchNormalization
evaluated *unfolded*, so the goldens also pin down the importer's BN-fold
arithmetic).

Usage::

    python -m compile.onnx_fixture [out_dir]   # default rust/tests/fixtures/onnx
"""

from __future__ import annotations

import json
import sys
import zlib
from pathlib import Path

import numpy as np

SEED = 0x5EED_1027  # project number 16ES1027, per the paper's acknowledgment


def _rng_stable(tag: str) -> np.random.Generator:
    return np.random.default_rng([SEED, zlib.crc32(tag.encode())])


# ---------------------------------------------------------------------------
# Protobuf wire-format encoder (the subset ONNX needs).
# ---------------------------------------------------------------------------


def _varint(v: int) -> bytes:
    out = bytearray()
    while True:
        b = v & 0x7F
        v >>= 7
        if v:
            out.append(b | 0x80)
        else:
            out.append(b)
            return bytes(out)


def _key(field: int, wire: int) -> bytes:
    return _varint((field << 3) | wire)


def _ld(field: int, payload: bytes) -> bytes:
    return _key(field, 2) + _varint(len(payload)) + payload


def _s(field: int, text: str) -> bytes:
    return _ld(field, text.encode())


def _i(field: int, v: int) -> bytes:
    return _key(field, 0) + _varint(v & 0xFFFFFFFFFFFFFFFF)


def _f(field: int, v: float) -> bytes:
    return _key(field, 5) + np.float32(v).tobytes()


def tensor_f32(name: str, array: np.ndarray) -> bytes:
    """TensorProto with FLOAT raw_data (the common exporter layout)."""
    a = np.ascontiguousarray(array, dtype=np.float32)
    b = b"".join(_i(1, d) for d in a.shape)
    b += _i(2, 1)  # data_type FLOAT
    b += _s(8, name)
    b += _ld(9, a.tobytes())  # raw_data, little-endian
    return b


def tensor_i64(name: str, values: list[int]) -> bytes:
    b = _i(1, len(values))
    b += _i(2, 7)  # data_type INT64
    b += _s(8, name)
    b += _ld(9, np.asarray(values, dtype="<i8").tobytes())
    return b


def attr_int(name: str, v: int) -> bytes:
    return _s(1, name) + _i(3, v) + _i(20, 2)


def attr_float(name: str, v: float) -> bytes:
    return _s(1, name) + _f(2, v) + _i(20, 1)


def attr_ints(name: str, values: list[int]) -> bytes:
    return _s(1, name) + b"".join(_i(8, v) for v in values) + _i(20, 7)


def onnx_node(op: str, inputs: list[str], outputs: list[str], attrs: list[bytes] = ()) -> bytes:
    b = b"".join(_s(1, i) for i in inputs)
    b += b"".join(_s(2, o) for o in outputs)
    b += _s(4, op)
    b += b"".join(_ld(5, a) for a in attrs)
    return b


def value_info(name: str, dims: list[int]) -> bytes:
    shape = b"".join(_ld(1, _i(1, d)) for d in dims)
    tensor_type = _i(1, 1) + _ld(2, shape)  # elem_type FLOAT + shape
    return _s(1, name) + _ld(2, _ld(1, tensor_type))


def onnx_model(nodes, initializers, inputs, outputs) -> bytes:
    g = b"".join(_ld(1, n) for n in nodes)
    g += b"".join(_ld(5, t) for t in initializers)
    g += b"".join(_ld(11, i) for i in inputs)
    g += b"".join(_ld(12, o) for o in outputs)
    opset = _s(1, "") + _i(2, 13)
    return _i(1, 8) + _ld(7, g) + _ld(8, opset)  # ir_version 8, opset 13


# ---------------------------------------------------------------------------
# NumPy float32 reference semantics (BN evaluated unfolded).
# ---------------------------------------------------------------------------


def ref_conv(x, w, b, pad):
    """NCHW-without-N conv: x (C,H,W), w (F,C,KH,KW), stride 1."""
    c, h, wd = x.shape
    f, _, kh, kw = w.shape
    xp = np.zeros((c, h + 2 * pad, wd + 2 * pad), dtype=np.float32)
    xp[:, pad : pad + h, pad : pad + wd] = x
    oh, ow = xp.shape[1] - kh + 1, xp.shape[2] - kw + 1
    out = np.empty((f, oh, ow), dtype=np.float32)
    for fi in range(f):
        for oy in range(oh):
            for ox in range(ow):
                acc = np.float32(b[fi])
                patch = xp[:, oy : oy + kh, ox : ox + kw]
                acc = np.float32(acc + np.sum(patch.astype(np.float32) * w[fi], dtype=np.float32))
                out[fi, oy, ox] = acc
    return out


def ref_bn(x, scale, beta, mean, var, eps):
    k = (scale / np.sqrt(var + np.float32(eps), dtype=np.float32)).astype(np.float32)
    return ((x - mean[:, None, None]) * k[:, None, None] + beta[:, None, None]).astype(np.float32)


def ref_maxpool2(x):
    c, h, w = x.shape
    return x[:, : h // 2 * 2, : w // 2 * 2].reshape(c, h // 2, 2, w // 2, 2).max(axis=(2, 4))


def ref_gap(x):
    c, h, w = x.shape
    inv = np.float32(1.0) / np.float32(h * w)
    return (x.reshape(c, -1).sum(axis=1, dtype=np.float32) * inv).reshape(c, 1, 1).astype(np.float32)


def relu(x):
    return np.maximum(x, np.float32(0.0))


def softmax(x):
    m = x.max()
    e = np.exp(x - m, dtype=np.float32)
    return (e / e.sum(dtype=np.float32)).astype(np.float32)


# ---------------------------------------------------------------------------
# Fixture models.
# ---------------------------------------------------------------------------


def _bn_params(tag: str, ch: int):
    g = _rng_stable(tag)
    scale = g.uniform(0.5, 1.5, ch).astype(np.float32)
    beta = g.uniform(-0.2, 0.2, ch).astype(np.float32)
    mean = g.uniform(-0.5, 0.5, ch).astype(np.float32)
    var = g.uniform(0.5, 2.0, ch).astype(np.float32)
    return scale, beta, mean, var


def _conv_w(tag: str, f: int, c: int, k: int):
    g = _rng_stable(tag)
    w = (g.standard_normal((f, c, k, k)) * (1.5 / np.sqrt(c * k * k))).astype(np.float32)
    b = g.uniform(-0.1, 0.1, f).astype(np.float32)
    return w, b


def resnet8():
    """ResNet-8-class TinyML classifier: stem + 2 residual stages + head.

    Input (1,3,8,8) → logits (1,10). Exercises Conv+BN+Relu folding,
    residual Add (identity and 1x1-conv projection skips), MaxPool,
    GlobalAveragePool, Flatten and Gemm.
    """
    nodes, inits = [], []
    eps = 1e-5

    def conv_bn(tag, x_name, out, f, c, k, pad, bn=True):
        w, b = _conv_w(f"{tag}_w", f, c, k)
        inits.append(tensor_f32(f"{tag}.w", w))
        inits.append(tensor_f32(f"{tag}.b", b))
        conv_out = f"{out}_conv" if bn else out
        nodes.append(
            onnx_node(
                "Conv",
                [x_name, f"{tag}.w", f"{tag}.b"],
                [conv_out],
                [attr_ints("pads", [pad] * 4), attr_ints("strides", [1, 1]), attr_int("group", 1)],
            )
        )
        params = None
        if bn:
            params = _bn_params(f"{tag}_bn", f)
            for suffix, arr in zip(("scale", "beta", "mean", "var"), params):
                inits.append(tensor_f32(f"{tag}.{suffix}", arr))
            nodes.append(
                onnx_node(
                    "BatchNormalization",
                    [conv_out, f"{tag}.scale", f"{tag}.beta", f"{tag}.mean", f"{tag}.var"],
                    [out],
                    [attr_float("epsilon", eps)],
                )
            )
        return (w, b, params)

    def fwd_conv_bn(x, p, pad):
        w, b, params = p
        y = ref_conv(x, w, b, pad)
        if params is not None:
            y = ref_bn(y, *params, eps)
        return y

    # Stem: 3 → 8 channels.
    stem = conv_bn("stem", "x", "stem_bn", 8, 3, 3, 1)
    nodes.append(onnx_node("Relu", ["stem_bn"], ["stem_r"]))
    # Stage 1: identity-skip residual block at 8 channels.
    s1a = conv_bn("s1a", "stem_r", "s1a_bn", 8, 8, 3, 1)
    nodes.append(onnx_node("Relu", ["s1a_bn"], ["s1a_r"]))
    s1b = conv_bn("s1b", "s1a_r", "s1b_bn", 8, 8, 3, 1)
    nodes.append(onnx_node("Add", ["s1b_bn", "stem_r"], ["s1_sum"]))
    nodes.append(onnx_node("Relu", ["s1_sum"], ["s1_r"]))
    nodes.append(
        onnx_node(
            "MaxPool",
            ["s1_r"],
            ["p1"],
            [attr_ints("kernel_shape", [2, 2]), attr_ints("strides", [2, 2])],
        )
    )
    # Stage 2: projection-skip residual block, 8 → 16 channels.
    s2a = conv_bn("s2a", "p1", "s2a_bn", 16, 8, 3, 1)
    nodes.append(onnx_node("Relu", ["s2a_bn"], ["s2a_r"]))
    s2b = conv_bn("s2b", "s2a_r", "s2b_bn", 16, 16, 3, 1)
    s2p = conv_bn("s2p", "p1", "s2_proj", 16, 8, 1, 0, bn=False)
    nodes.append(onnx_node("Add", ["s2b_bn", "s2_proj"], ["s2_sum"]))
    nodes.append(onnx_node("Relu", ["s2_sum"], ["s2_r"]))
    nodes.append(
        onnx_node(
            "MaxPool",
            ["s2_r"],
            ["p2"],
            [attr_ints("kernel_shape", [2, 2]), attr_ints("strides", [2, 2])],
        )
    )
    # Stage 3: plain Conv+BN+Relu at 32 channels, then the head.
    s3 = conv_bn("s3", "p2", "s3_bn", 32, 16, 3, 1)
    nodes.append(onnx_node("Relu", ["s3_bn"], ["s3_r"]))
    nodes.append(onnx_node("GlobalAveragePool", ["s3_r"], ["gap"]))
    nodes.append(onnx_node("Flatten", ["gap"], ["flat"], [attr_int("axis", 1)]))
    g = _rng_stable("head_fc")
    fc_w = (g.standard_normal((32, 10)) * 0.3).astype(np.float32)
    fc_b = g.uniform(-0.1, 0.1, 10).astype(np.float32)
    inits.append(tensor_f32("head.w", fc_w))
    inits.append(tensor_f32("head.b", fc_b))
    nodes.append(onnx_node("Gemm", ["flat", "head.w", "head.b"], ["logits"], [attr_int("transB", 0)]))

    model = onnx_model(nodes, inits, [value_info("x", [1, 3, 8, 8])], [value_info("logits", [1, 10])])

    x = _rng_stable("resnet8_input").uniform(-1.0, 1.0, (3, 8, 8)).astype(np.float32)
    h = relu(fwd_conv_bn(x, stem, 1))
    a = relu(fwd_conv_bn(h, s1a, 1))
    b = fwd_conv_bn(a, s1b, 1)
    h = ref_maxpool2(relu((b + h).astype(np.float32)))
    a = relu(fwd_conv_bn(h, s2a, 1))
    b = fwd_conv_bn(a, s2b, 1)
    p = fwd_conv_bn(h, s2p, 0)
    h = ref_maxpool2(relu((b + p).astype(np.float32)))
    h = relu(fwd_conv_bn(h, s3, 1))
    flat = ref_gap(h).reshape(1, 32)
    logits = (flat @ fc_w + fc_b).astype(np.float32)
    return model, x.reshape(1, 3, 8, 8), logits


def tiny_convnet():
    """Conv(pad 0) → Relu → MaxPool → Flatten → Gemm(transB=1)."""
    w, b = _conv_w("tiny_conv", 4, 1, 3)
    g = _rng_stable("tiny_fc_t")
    fc_wt = (g.standard_normal((5, 16)) * 0.4).astype(np.float32)  # stored (N,K)
    fc_b = g.uniform(-0.2, 0.2, 5).astype(np.float32)
    nodes = [
        onnx_node("Conv", ["x", "c.w", "c.b"], ["c1"], [attr_ints("pads", [0, 0, 0, 0])]),
        onnx_node("Relu", ["c1"], ["r1"]),
        onnx_node(
            "MaxPool",
            ["r1"],
            ["p1"],
            [attr_ints("kernel_shape", [2, 2]), attr_ints("strides", [2, 2])],
        ),
        onnx_node("Flatten", ["p1"], ["flat"], [attr_int("axis", 1)]),
        onnx_node("Gemm", ["flat", "f.w", "f.b"], ["logits"], [attr_int("transB", 1)]),
    ]
    inits = [
        tensor_f32("c.w", w),
        tensor_f32("c.b", b),
        tensor_f32("f.w", fc_wt),
        tensor_f32("f.b", fc_b),
    ]
    model = onnx_model(nodes, inits, [value_info("x", [1, 1, 6, 6])], [value_info("logits", [1, 5])])

    x = _rng_stable("tiny_convnet_input").uniform(-1.0, 1.0, (1, 6, 6)).astype(np.float32)
    h = ref_maxpool2(relu(ref_conv(x, w, b, 0)))
    logits = (h.reshape(1, 16) @ fc_wt.T + fc_b).astype(np.float32)
    return model, x.reshape(1, 1, 6, 6), logits


def tiny_concat_bn():
    """Two conv branches (one BN-folded) → channel Concat → GAP → MatMul → Softmax."""
    wa, ba = _conv_w("cat_a", 3, 2, 1)
    wb, bb = _conv_w("cat_b", 3, 2, 3)
    bn = _bn_params("cat_bn", 3)
    eps = 1e-3
    g = _rng_stable("cat_fc")
    fc_w = (g.standard_normal((6, 4)) * 0.5).astype(np.float32)
    nodes = [
        onnx_node("Conv", ["x", "a.w", "a.b"], ["a1"]),
        onnx_node(
            "BatchNormalization",
            ["a1", "bn.scale", "bn.beta", "bn.mean", "bn.var"],
            ["a_bn"],
            [attr_float("epsilon", eps)],
        ),
        onnx_node("Relu", ["a_bn"], ["a_r"]),
        onnx_node("Conv", ["x", "b.w", "b.b"], ["b1"], [attr_ints("pads", [1, 1, 1, 1])]),
        onnx_node("Relu", ["b1"], ["b_r"]),
        onnx_node("Concat", ["a_r", "b_r"], ["cat"], [attr_int("axis", 1)]),
        onnx_node("GlobalAveragePool", ["cat"], ["gap"]),
        onnx_node("Flatten", ["gap"], ["flat"]),
        onnx_node("MatMul", ["flat", "f.w"], ["logits"]),
        onnx_node("Softmax", ["logits"], ["probs"], [attr_int("axis", -1)]),
    ]
    inits = [
        tensor_f32("a.w", wa),
        tensor_f32("a.b", ba),
        tensor_f32("bn.scale", bn[0]),
        tensor_f32("bn.beta", bn[1]),
        tensor_f32("bn.mean", bn[2]),
        tensor_f32("bn.var", bn[3]),
        tensor_f32("b.w", wb),
        tensor_f32("b.b", bb),
        tensor_f32("f.w", fc_w),
    ]
    model = onnx_model(nodes, inits, [value_info("x", [1, 2, 4, 4])], [value_info("probs", [1, 4])])

    x = _rng_stable("tiny_concat_input").uniform(-1.0, 1.0, (2, 4, 4)).astype(np.float32)
    a = relu(ref_bn(ref_conv(x, wa, ba, 0), *bn, eps))
    b = relu(ref_conv(x, wb, bb, 1))
    h = ref_gap(np.concatenate([a, b], axis=0))
    probs = softmax((h.reshape(1, 6) @ fc_w).astype(np.float32))
    return model, x.reshape(1, 2, 4, 4), probs


FIXTURES = {
    "resnet8": resnet8,
    "tiny_convnet": tiny_convnet,
    "tiny_concat_bn": tiny_concat_bn,
}


def write_fixtures(out_dir: Path) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    for name, build in FIXTURES.items():
        model, x, y = build()
        (out_dir / f"{name}.onnx").write_bytes(model)
        golden = {
            "input": {"shape": list(x.shape), "data": [float(v) for v in x.reshape(-1)]},
            "output": {"shape": list(y.shape), "data": [float(v) for v in y.reshape(-1)]},
        }
        (out_dir / f"{name}.expected.json").write_text(json.dumps(golden, indent=1) + "\n")
        print(f"wrote {out_dir / name}.onnx ({len(model)} bytes), output shape {list(y.shape)}")


def main() -> None:
    default = Path(__file__).resolve().parents[2] / "rust" / "tests" / "fixtures" / "onnx"
    out = Path(sys.argv[1]) if len(sys.argv) > 1 else default
    write_fixtures(out)


if __name__ == "__main__":
    main()
