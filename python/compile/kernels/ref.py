"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package is validated against these references by
``python/tests/``; the same semantics are re-implemented natively in Rust
(``rust/src/ops/``) so the request path can cross-check PJRT numerics.
"""

import jax.numpy as jnp

_I16_MIN = -32768
_I16_MAX = 32767


def fc_ref(x, w, b):
    """x (M,K) f32, w (K,N) f32, b (N,) f32 -> (M,N) f32."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32) + b


def conv_fixed_ref(x, weights, *, acc_dtype, out_dtype, shift=0):
    """Direct-form valid cross-correlation with fixed weights.

    x (C,H,W), weights (F,C,KH,KW) -> (F, H-KH+1, W-KW+1); accumulate in
    ``acc_dtype``, arithmetic right shift by ``shift``, saturate when the
    output type is int16.
    """
    x = jnp.asarray(x)
    weights = jnp.asarray(weights)
    f, c, kh, kw = weights.shape
    _, h, w = x.shape
    oh, ow = h - kh + 1, w - kw + 1
    xa = x.astype(acc_dtype)
    acc = jnp.zeros((f, oh, ow), acc_dtype)
    for a in range(kh):
        for b in range(kw):
            window = xa[:, a : a + oh, b : b + ow]
            tap = weights[:, :, a, b].astype(acc_dtype)
            acc = acc + jnp.tensordot(tap, window, axes=((1,), (0,)))
    if shift:
        acc = jnp.right_shift(acc, shift)
    if out_dtype == jnp.int16:
        acc = jnp.clip(acc, _I16_MIN, _I16_MAX)
    return acc.astype(out_dtype)


def conv_i16_ref(x, weights, shift=8):
    return conv_fixed_ref(
        x, weights, acc_dtype=jnp.int32, out_dtype=jnp.int16, shift=shift
    )


def conv_f32_ref(x, weights):
    return conv_fixed_ref(
        x, weights, acc_dtype=jnp.float32, out_dtype=jnp.float32, shift=0
    )


def relu_ref(x):
    return jnp.maximum(x, 0)


def maxpool2_ref(x):
    """2x2 max pool, stride 2, over (C,H,W); trailing odd row/col dropped."""
    c, h, w = x.shape
    h2, w2 = h // 2, w // 2
    x = x[:, : h2 * 2, : w2 * 2]
    x = x.reshape(c, h2, 2, w2, 2)
    return x.max(axis=(2, 4))
