"""Pallas fully-connected kernels (paper roles 1 and 2).

Role 1 (``fc``) is a blocked matmul + bias: the grid iterates over
(M/bm, N/bn, K/bk) tiles, accumulating partial products directly into the
output block. This mirrors the FPGA role's MAC array streaming K.

Role 2 (``fc_barrier``) is the same computation with an explicit *barrier*
between the accumulation phase and the write-back phase: partial sums live
in a VMEM scratch accumulator and only after the final K step (the barrier
point, where every PE's partial product must have arrived) is the biased
result committed to HBM. On the paper's FPGA datapath this barrier is the
synchronization stage of the multi-PE reduction tree; on TPU it is the
``@pl.when(last_k)`` gated write-back from VMEM scratch.

Tiling: blocks are MXU-shaped (up to 128x128). Dimensions smaller than the
block take the full dimension; larger dimensions must be multiples of the
block (asserted) so no masked partial tiles are needed.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-flavored scratch memory spaces work under interpret=True too
    from jax.experimental.pallas import tpu as pltpu

    _HAVE_PLTPU = True
except ImportError:  # pragma: no cover
    _HAVE_PLTPU = False

_MAX_BLOCK = 128


def _block(dim: int, cap: int = _MAX_BLOCK) -> int:
    """Pick a tile size: the whole dim if small, else the cap (must divide)."""
    if dim <= cap:
        return dim
    if dim % cap != 0:
        raise ValueError(
            f"dimension {dim} must be a multiple of the {cap} tile; "
            "pad inputs at the caller"
        )
    return cap


def _fc_kernel(x_ref, w_ref, b_ref, o_ref):
    """Role 1: accumulate x@w tiles into o, seeding with the bias."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _seed():
        o_ref[...] = jnp.broadcast_to(b_ref[...], o_ref.shape)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _fc_barrier_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref):
    """Role 2: accumulate into VMEM scratch; barrier, then write back."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    # ---- barrier: every partial product for this (i, j) tile has landed ----
    @pl.when(k == pl.num_programs(2) - 1)
    def _commit():
        o_ref[...] = acc_ref[...] + b_ref[...][None, :]


def _fc_call(kernel, x, w, b, *, barrier: bool):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"
    bm, bk, bn = _block(m), _block(k), _block(n)
    grid = (m // bm, n // bn, k // bk)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
    ]
    out_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
    scratch = []
    if barrier:
        if _HAVE_PLTPU:
            scratch = [pltpu.VMEM((bm, bn), jnp.float32)]
        else:  # pragma: no cover
            scratch = [pl.ANY((bm, bn), jnp.float32)]

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=scratch,
        interpret=True,
    )(x, w, b)


@jax.jit
def fc(x, w, b):
    """Role 1 — fully connected, float32: ``x @ w + b``.

    x: (M, K) f32, w: (K, N) f32, b: (N,) f32 -> (M, N) f32.
    """
    return _fc_call(_fc_kernel, x, w, b, barrier=False)


@jax.jit
def fc_barrier(x, w, b):
    """Role 2 — fully connected with barrier, float32 (same math as role 1).

    Numerically identical to :func:`fc`; structurally the accumulation is
    staged in VMEM scratch and committed only after the barrier (last K
    step), matching the paper's barrier-synchronized FC datapath.
    """
    return _fc_call(_fc_barrier_kernel, x, w, b, barrier=True)
