"""Layer-1 Pallas kernels for the four paper roles (+ generic variants).

Role 1  fc            - fully connected, float32
Role 2  fc_barrier    - fully connected with an explicit barrier phase, float32
Role 3  conv 5x5      - 1 filter, fixed weights, int16
Role 4  conv 3x3      - 2 filters, fixed weights, int16

All kernels are lowered with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); the BlockSpecs still express the HBM<->VMEM schedule a
real TPU lowering would use — see DESIGN.md "Hardware adaptation".
"""

from .fc import fc, fc_barrier
from .conv import make_fixed_conv, conv_fixed_i16, conv_fixed_f32

__all__ = [
    "fc",
    "fc_barrier",
    "make_fixed_conv",
    "conv_fixed_i16",
    "conv_fixed_f32",
]
