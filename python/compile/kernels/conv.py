"""Pallas fixed-weight convolution kernels (paper roles 3 and 4).

The paper's conv roles bake the filter weights into the bitstream
("fixed weights" — constant multipliers become shift/add LUT logic, which
is why Table I shows so few DSPs for a 25-tap filter). We mirror that: the
weights are *compile-time constants* closed over by the kernel, so they
lower into the HLO as literals, exactly like a weight-fixed datapath.

Layout: x is (C, H, W); the kernel produces (F, OH, OW) with
OH = H - KH + 1, OW = W - KW + 1 ("valid" convolution, cross-correlation
orientation like TF). int16 inputs accumulate in int32 and are rescaled by
an arithmetic right shift, then saturated back to int16 — the standard
fixed-point pipeline of an FPGA MAC tree.

The grid runs over output-row bands so each step works on a (C, band+KH-1, W)
input window in VMEM — the Pallas analogue of the FPGA role's line buffer
(the BlockSpec index_map implements the sliding window the AXI burst
scheduler would perform).
"""

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_I16_MIN = -32768
_I16_MAX = 32767


def _conv_band_kernel(x_ref, o_ref, *, weights, acc_dtype, out_dtype, shift):
    """One output band: direct-form conv as KH*KW shifted tensordots."""
    x = x_ref[...].astype(acc_dtype)  # (C, band + KH - 1, W)
    f, c, kh, kw = weights.shape
    oh = o_ref.shape[1]
    ow = o_ref.shape[2]
    # Fully unrolled tap loop with *Python-scalar* taps: Pallas forbids the
    # kernel from closing over array constants, and scalar immediates are
    # exactly what fixed weights become on the FPGA — each tap is its own
    # constant multiplier (zero taps are elided outright, the same dead
    # logic the synthesizer would trim). One tap == one MAC-tree stage.
    planes = []
    for fi in range(f):
        acc = jnp.zeros((oh, ow), acc_dtype)
        for ci in range(c):
            xc = x[ci]
            for a in range(kh):
                for b in range(kw):
                    tap = weights[fi, ci, a, b].item()
                    if tap == 0:
                        continue
                    acc = acc + xc[a : a + oh, b : b + ow] * tap
        planes.append(acc)
    acc = jnp.stack(planes)
    if shift:
        acc = jnp.right_shift(acc, shift)
    if out_dtype == jnp.int16:
        acc = jnp.clip(acc, _I16_MIN, _I16_MAX)
    o_ref[...] = acc.astype(out_dtype)


def make_fixed_conv(weights, *, in_dtype, acc_dtype, out_dtype, shift=0,
                    band=8):
    """Build a fixed-weight conv: ``x (C,H,W) -> (F, H-KH+1, W-KW+1)``.

    weights: numpy/jnp array (F, C, KH, KW), baked as HLO constants.
    shift:   arithmetic right shift applied to the accumulator (fixed-point
             rescale); 0 for float.
    band:    output rows computed per grid step (line-buffer height).
    """
    weights = np.asarray(weights)
    f, c, kh, kw = weights.shape

    kernel = functools.partial(
        _conv_band_kernel,
        weights=weights,
        acc_dtype=acc_dtype,
        out_dtype=out_dtype,
        shift=shift,
    )

    def conv(x):
        cc, h, w = x.shape
        assert cc == c, f"expected {c} input channels, got {cc}"
        assert x.dtype == in_dtype, f"expected {in_dtype}, got {x.dtype}"
        oh, ow = h - kh + 1, w - kw + 1
        assert oh > 0 and ow > 0, "input smaller than the filter"
        # Whole image per call: (C,H,W) fits VMEM for the paper's sizes
        # (28x28 int16 = 1.5 KiB; even 224x224x3 f32 = 588 KiB < 16 MiB VMEM).
        # Overlapping line-buffer banding (the FPGA schedule) is documented
        # in DESIGN.md; BlockSpec windows must not overlap, so banding would
        # use a halo-exchange scratch — unnecessary at these sizes.
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct((f, oh, ow), out_dtype),
            interpret=True,
        )(x)

    return conv


def conv_fixed_i16(weights, shift=8):
    """Fixed-weight int16 conv (roles 3 and 4): i32 accumulate, >>shift,
    saturate to int16."""
    return make_fixed_conv(
        weights,
        in_dtype=jnp.int16,
        acc_dtype=jnp.int32,
        out_dtype=jnp.int16,
        shift=shift,
    )


def conv_fixed_f32(weights):
    """Float32 variant of the fixed-weight conv (used by the MNIST CNN)."""
    return make_fixed_conv(
        weights,
        in_dtype=jnp.float32,
        acc_dtype=jnp.float32,
        out_dtype=jnp.float32,
        shift=0,
    )
