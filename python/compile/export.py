"""CLI: export demo model bundles in the Rust runtime's `tf::model`
serving format (directories of `model.json`).

Usage:  python -m compile.export --out-dir /tmp/demo-bundles
Then:   tf-fpga serve --model /tmp/demo-bundles/tiny_fc

Writes three bundles:
  mnist/         whole-model CNN, batched along dim 0 (servable)
  mnist_layers/  per-layer CNN with named weight-artifact references
  tiny_fc/       dense model with weights embedded in the GraphDef
"""

import argparse

from . import model


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="demo-bundles")
    ap.add_argument("--max-batch", type=int, default=32,
                    help="batch dim of the whole-model mnist bundle")
    ns = ap.parse_args()
    for path in model.export(ns.out_dir, max_batch=ns.max_batch):
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
