"""L1 correctness: Pallas FC kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes; fixed cases pin the paper's role-1/2 workload.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

import sys, os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile.kernels import fc, fc_barrier
from compile.kernels.ref import fc_ref

# Dims: small arbitrary (<=128, taken whole as one block) or 128-multiples.
_dim = st.one_of(
    st.integers(1, 48),
    st.sampled_from([64, 96, 128, 256]),
)


def _rand(shape, seed):
    return np.random.default_rng(seed).normal(0, 1, shape).astype(np.float32)


class TestFcFixed:
    def test_role1_shape(self):
        x, w, b = _rand((64, 64), 1), _rand((64, 64), 2), _rand(64, 3)
        out = fc(x, w, b)
        assert out.shape == (64, 64)
        assert out.dtype == jnp.float32

    def test_role1_matches_ref(self):
        x, w, b = _rand((64, 64), 4), _rand((64, 64), 5), _rand(64, 6)
        np.testing.assert_allclose(fc(x, w, b), fc_ref(x, w, b), rtol=1e-5)

    def test_role2_matches_ref(self):
        x, w, b = _rand((64, 64), 7), _rand((64, 64), 8), _rand(64, 9)
        np.testing.assert_allclose(
            fc_barrier(x, w, b), fc_ref(x, w, b), rtol=1e-5
        )

    def test_role1_role2_identical(self):
        """Roles 1 and 2 are numerically the same computation."""
        x, w, b = _rand((64, 64), 10), _rand((64, 64), 11), _rand(64, 12)
        np.testing.assert_allclose(fc(x, w, b), fc_barrier(x, w, b), rtol=1e-6)

    def test_multiblock_k_accumulation(self):
        """K > 128 exercises the multi-step accumulation (grid k dim)."""
        x, w, b = _rand((16, 256), 13), _rand((256, 8), 14), _rand(8, 15)
        np.testing.assert_allclose(
            fc(x, w, b), fc_ref(x, w, b), rtol=1e-4, atol=1e-4
        )

    def test_multiblock_mn(self):
        x, w, b = _rand((256, 64), 16), _rand((64, 256), 17), _rand(256, 18)
        np.testing.assert_allclose(
            fc(x, w, b), fc_ref(x, w, b), rtol=1e-4, atol=1e-4
        )

    def test_bias_broadcast(self):
        x = np.zeros((4, 4), np.float32)
        w = np.zeros((4, 4), np.float32)
        b = np.arange(4, dtype=np.float32)
        out = np.asarray(fc(x, w, b))
        for row in out:
            np.testing.assert_array_equal(row, b)

    def test_indivisible_large_dim_raises(self):
        x, w, b = _rand((130, 4), 19), _rand((4, 4), 20), _rand(4, 21)
        with pytest.raises(ValueError, match="multiple"):
            fc(x, w, b)


@settings(max_examples=25, deadline=None)
@given(m=_dim, k=_dim, n=_dim, seed=st.integers(0, 2**31 - 1))
def test_fc_property(m, k, n, seed):
    g = np.random.default_rng(seed)
    x = g.normal(0, 1, (m, k)).astype(np.float32)
    w = g.normal(0, 1, (k, n)).astype(np.float32)
    b = g.normal(0, 1, (n,)).astype(np.float32)
    np.testing.assert_allclose(
        fc(x, w, b), fc_ref(x, w, b), rtol=2e-4, atol=2e-4
    )


@settings(max_examples=10, deadline=None)
@given(m=_dim, k=_dim, n=_dim, seed=st.integers(0, 2**31 - 1))
def test_fc_barrier_property(m, k, n, seed):
    g = np.random.default_rng(seed)
    x = g.normal(0, 1, (m, k)).astype(np.float32)
    w = g.normal(0, 1, (k, n)).astype(np.float32)
    b = g.normal(0, 1, (n,)).astype(np.float32)
    np.testing.assert_allclose(
        fc_barrier(x, w, b), fc_ref(x, w, b), rtol=2e-4, atol=2e-4
    )
