"""L2 correctness: role entry points + the MNIST CNN vs pure-jnp refs."""

import numpy as np
import jax.numpy as jnp

import sys, os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile import model
from compile.kernels.ref import fc_ref, conv_i16_ref


def test_weights_deterministic():
    w1 = model.role_weights()
    w2 = model.role_weights()
    assert set(w1) == set(w2)
    for k in w1:
        np.testing.assert_array_equal(w1[k], w2[k])


def test_role1_matches_ref():
    x = np.random.default_rng(0).normal(0, 1, (64, 64)).astype(np.float32)
    w = model.role_weights()
    np.testing.assert_allclose(
        model.role1_fc(x, w["role1/w"], w["role1/b"]),
        fc_ref(x, w["role1/w"], w["role1/b"]),
        rtol=1e-4,
    )


def test_role2_matches_ref():
    x = np.random.default_rng(1).normal(0, 1, (64, 64)).astype(np.float32)
    w = model.role_weights()
    np.testing.assert_allclose(
        model.role2_fc_barrier(x, w["role2/w"], w["role2/b"]),
        fc_ref(x, w["role2/w"], w["role2/b"]),
        rtol=1e-4,
    )


def test_role3_matches_ref():
    x = np.random.default_rng(2).integers(-256, 256, (1, 28, 28)).astype(np.int16)
    w = model.role_weights()
    np.testing.assert_array_equal(
        model.role3_conv5x5(x),
        conv_i16_ref(x, w["role3/w"], shift=model.CONV_SHIFT),
    )


def test_role4_matches_ref():
    x = np.random.default_rng(3).integers(-256, 256, (1, 28, 28)).astype(np.int16)
    w = model.role_weights()
    np.testing.assert_array_equal(
        model.role4_conv3x3(x),
        conv_i16_ref(x, w["role4/w"], shift=model.CONV_SHIFT),
    )


def test_cnn_shapes():
    x = np.random.default_rng(4).normal(0, 1, (4, 1, 28, 28)).astype(np.float32)
    out = model.mnist_cnn(x)
    assert out.shape == (4, 10)
    assert out.dtype == jnp.float32


def test_cnn_matches_ref():
    x = np.random.default_rng(5).normal(0, 1, (8, 1, 28, 28)).astype(np.float32)
    np.testing.assert_allclose(
        model.mnist_cnn(x), model.mnist_cnn_ref(x), rtol=1e-4, atol=1e-4
    )


def test_cnn_batch_independence():
    """Each batch element is independent (vmap correctness)."""
    g = np.random.default_rng(6)
    x = g.normal(0, 1, (3, 1, 28, 28)).astype(np.float32)
    full = np.asarray(model.mnist_cnn(x))
    for i in range(3):
        single = np.asarray(model.mnist_cnn(x[i : i + 1]))
        np.testing.assert_allclose(full[i], single[0], rtol=1e-5, atol=1e-5)


def test_entry_point_table_consistent():
    assert set(model.ENTRY_POINTS) == set(model.ROLE_SHAPES)
