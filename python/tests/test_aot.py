"""AOT lowering regression tests.

The critical one: HLO text must embed large constants verbatim.
`as_hlo_text()`'s default elides them as `constant({...})`, which the Rust
side's xla_extension 0.5.1 text parser silently reads back as *zeros* —
baked weights would vanish (this bit us; see aot.py).
"""

import sys, os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model


def test_hlo_text_embeds_large_constants():
    w = np.random.default_rng(0).normal(0, 1, (64, 32)).astype(np.float32)

    def fn(x):
        return (x @ jnp.asarray(w),)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((1, 64), jnp.float32))
    txt = aot.to_hlo_text(lowered)
    assert "constant({...})" not in txt, "elided constants would decode as zeros"
    # A distinctive weight value must appear literally in the text.
    assert f"f32[64,32]" in txt


def test_all_entry_points_lower_without_elision():
    for name in model.ENTRY_POINTS:
        txt = aot.to_hlo_text(aot.lower_entry(name))
        assert "{...}" not in txt, f"{name}: elided constant in HLO text"
        assert "ENTRY" in txt, f"{name}: not HLO text?"


def test_entry_point_shapes_match_manifest_decl():
    _DT = {"f32": np.float32, "i16": np.int16, "i32": np.int32}
    for name, spec in model.ROLE_SHAPES.items():
        fn = model.ENTRY_POINTS[name]
        args = [
            np.zeros(shape, _DT[dt]) for _, shape, dt in spec["inputs"]
        ]
        out = fn(*args)
        out_shape, out_dt = spec["output"]
        assert tuple(out.shape) == tuple(out_shape), f"{name}: {out.shape}"
        assert out.dtype == _DT[out_dt], f"{name}: {out.dtype}"


def test_conv_roles_bake_weights_as_constants():
    """Conv roles take only the activation: weights must be baked."""
    for name in ["role3_conv5x5", "role4_conv3x3"]:
        spec = model.ROLE_SHAPES[name]
        assert len(spec["inputs"]) == 1, f"{name} must be weight-fixed"


def test_fc_roles_stream_weights_at_runtime():
    for name in ["role1_fc", "role2_fc_barrier"]:
        spec = model.ROLE_SHAPES[name]
        assert len(spec["inputs"]) == 3, f"{name} is a generic FC datapath"
