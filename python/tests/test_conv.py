"""L1 correctness: fixed-weight Pallas conv kernels vs the jnp oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

import sys, os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile.kernels import conv_fixed_i16, conv_fixed_f32, make_fixed_conv
from compile.kernels.ref import conv_i16_ref, conv_f32_ref


def _w_i16(f, c, kh, kw, seed):
    return (
        np.random.default_rng(seed).integers(-128, 128, (f, c, kh, kw))
        .astype(np.int16)
    )


def _x_i16(c, h, w, seed):
    return (
        np.random.default_rng(seed).integers(-256, 256, (c, h, w))
        .astype(np.int16)
    )


class TestConvFixedI16:
    def test_role3_shape(self):
        w = _w_i16(1, 1, 5, 5, 0)
        out = conv_fixed_i16(w)(_x_i16(1, 28, 28, 1))
        assert out.shape == (1, 24, 24)
        assert out.dtype == jnp.int16

    def test_role3_matches_ref(self):
        w = _w_i16(1, 1, 5, 5, 2)
        x = _x_i16(1, 28, 28, 3)
        np.testing.assert_array_equal(conv_fixed_i16(w)(x), conv_i16_ref(x, w))

    def test_role4_shape(self):
        w = _w_i16(2, 1, 3, 3, 4)
        out = conv_fixed_i16(w)(_x_i16(1, 28, 28, 5))
        assert out.shape == (2, 26, 26)

    def test_role4_matches_ref(self):
        w = _w_i16(2, 1, 3, 3, 6)
        x = _x_i16(1, 28, 28, 7)
        np.testing.assert_array_equal(conv_fixed_i16(w)(x), conv_i16_ref(x, w))

    def test_saturation(self):
        """Large inputs with shift=0 must clip to int16, not wrap."""
        w = np.full((1, 1, 3, 3), 127, np.int16)
        x = np.full((1, 8, 8), 32000, np.int16)
        out = np.asarray(conv_fixed_i16(w, shift=0)(x))
        assert (out == 32767).all()
        out_neg = np.asarray(conv_fixed_i16(w, shift=0)(-x))
        assert (out_neg == -32768).all()

    def test_shift_rescale(self):
        w = np.zeros((1, 1, 3, 3), np.int16)
        w[0, 0, 1, 1] = 64  # identity tap * 64
        x = _x_i16(1, 10, 10, 8)
        out = np.asarray(conv_fixed_i16(w, shift=6)(x))  # *64 >> 6 == id
        np.testing.assert_array_equal(out, x[:, 1:9, 1:9])

    def test_wrong_channels_raises(self):
        w = _w_i16(1, 2, 3, 3, 9)
        with pytest.raises(AssertionError, match="channels"):
            conv_fixed_i16(w)(_x_i16(1, 8, 8, 10))

    def test_wrong_dtype_raises(self):
        w = _w_i16(1, 1, 3, 3, 11)
        with pytest.raises(AssertionError, match="expected"):
            conv_fixed_i16(w)(np.zeros((1, 8, 8), np.float32))

    def test_too_small_input_raises(self):
        w = _w_i16(1, 1, 5, 5, 12)
        with pytest.raises(AssertionError, match="smaller"):
            conv_fixed_i16(w)(_x_i16(1, 4, 4, 13))


class TestConvFixedF32:
    def test_matches_ref(self):
        g = np.random.default_rng(20)
        w = g.normal(0, 1, (4, 2, 5, 5)).astype(np.float32)
        x = g.normal(0, 1, (2, 13, 13)).astype(np.float32)
        np.testing.assert_allclose(
            conv_fixed_f32(w)(x), conv_f32_ref(x, w), rtol=1e-5, atol=1e-5
        )

    def test_identity_kernel(self):
        w = np.zeros((1, 1, 1, 1), np.float32)
        w[0, 0, 0, 0] = 1.0
        x = np.random.default_rng(21).normal(0, 1, (1, 6, 6)).astype(np.float32)
        np.testing.assert_allclose(conv_fixed_f32(w)(x), x)


@settings(max_examples=20, deadline=None)
@given(
    f=st.integers(1, 3),
    c=st.integers(1, 3),
    kh=st.sampled_from([1, 3, 5]),
    kw=st.sampled_from([1, 3, 5]),
    h=st.integers(5, 20),
    w=st.integers(5, 20),
    shift=st.integers(0, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_i16_property(f, c, kh, kw, h, w, shift, seed):
    weights = _w_i16(f, c, kh, kw, seed)
    x = _x_i16(c, h, w, seed + 1)
    got = conv_fixed_i16(weights, shift=shift)(x)
    want = conv_i16_ref(x, weights, shift=shift)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(
    f=st.integers(1, 4),
    c=st.integers(1, 3),
    k=st.sampled_from([1, 3, 5]),
    h=st.integers(6, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_f32_property(f, c, k, h, seed):
    g = np.random.default_rng(seed)
    weights = g.normal(0, 1, (f, c, k, k)).astype(np.float32)
    x = g.normal(0, 1, (c, h, h)).astype(np.float32)
    np.testing.assert_allclose(
        conv_fixed_f32(weights)(x),
        conv_f32_ref(x, weights),
        rtol=1e-4,
        atol=1e-4,
    )
