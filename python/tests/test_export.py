"""Model-bundle export: structure, references, and f32 exactness of the
`model.json` documents the Rust runtime loads via `ModelBundle::load`."""

import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile import model


def _load(tmp_path, name):
    with open(os.path.join(tmp_path, name, "model.json")) as f:
        return json.load(f)


def _check_graph_well_formed(doc):
    seen = set()
    for node in doc["graph"]["nodes"]:
        assert node["name"] not in seen, f"duplicate node {node['name']}"
        for inp in node.get("inputs", []):
            assert inp in seen, f"{node['name']} uses {inp} before definition"
        seen.add(node["name"])
    for sig in doc["signatures"]:
        for ep in sig["inputs"] + sig["outputs"]:
            assert ep["node"] in seen, f"endpoint {ep['name']} -> unknown {ep['node']}"


def test_export_writes_all_bundles(tmp_path):
    paths = model.export(str(tmp_path))
    assert len(paths) == 3
    for name in ["mnist", "mnist_layers", "tiny_fc"]:
        doc = _load(tmp_path, name)
        assert doc["format"] == model.BUNDLE_FORMAT
        assert doc["version"] == model.BUNDLE_VERSION
        assert doc["name"] == name
        assert doc["signatures"], name
        _check_graph_well_formed(doc)


def test_mnist_bundle_batches_along_dim0(tmp_path):
    model.export(str(tmp_path), max_batch=16)
    doc = _load(tmp_path, "mnist")
    (sig,) = doc["signatures"]
    assert sig["inputs"][0]["shape"] == [16, 1, 28, 28]
    assert sig["outputs"][0]["shape"] == [16, 10]
    assert doc["artifacts"] == []


def test_layers_bundle_lists_weight_artifact_refs(tmp_path):
    model.export(str(tmp_path))
    doc = _load(tmp_path, "mnist_layers")
    assert doc["artifacts"] == [
        "cnn/conv1", "cnn/conv2", "cnn/fc1_b", "cnn/fc1_w", "cnn/fc2_b", "cnn/fc2_w",
    ]
    ops = [n["op"] for n in doc["graph"]["nodes"]]
    assert ops.count("conv_fixed_f32") == 2
    assert ops.count("fc_fixed") == 2


def test_tiny_fc_embedded_weights_round_trip_exactly(tmp_path):
    model.export(str(tmp_path))
    doc = _load(tmp_path, "tiny_fc")
    w_ref, b_ref = model.tiny_fc_weights()
    by_name = {n["name"]: n for n in doc["graph"]["nodes"]}
    w = np.asarray(by_name["w"]["tensor"]["data"], np.float32).reshape(w_ref.shape)
    b = np.asarray(by_name["b"]["tensor"]["data"], np.float32).reshape(b_ref.shape)
    # json floats are shortest-round-trip f64; narrowing back to f32 must
    # reproduce the original bits.
    np.testing.assert_array_equal(w, w_ref)
    np.testing.assert_array_equal(b, b_ref)
    assert by_name["w"]["tensor"]["shape"] == list(w_ref.shape)
    assert by_name["fc"]["inputs"] == ["x", "w", "b"]
    assert by_name["fc"]["device"] == "fpga"


def test_non_finite_weights_fail_export_loudly(tmp_path):
    import pytest

    doc = model.tiny_fc_bundle()
    for node in doc["graph"]["nodes"]:
        if node["name"] == "w":
            node["tensor"]["data"][0] = float("nan")
    with pytest.raises(ValueError):
        model.write_bundle(doc, str(tmp_path / "bad"))


def test_export_is_deterministic(tmp_path):
    a_dir = tmp_path / "a"
    b_dir = tmp_path / "b"
    model.export(str(a_dir))
    model.export(str(b_dir))
    for name in ["mnist", "mnist_layers", "tiny_fc"]:
        with open(a_dir / name / "model.json") as f:
            a = f.read()
        with open(b_dir / name / "model.json") as f:
            b = f.read()
        assert a == b, f"{name} export not deterministic"
