"""The ONNX fixture generator must be deterministic and well-framed.

A minimal protobuf walker (mirroring the Rust decoder's framing rules)
checks the emitted bytes; byte-for-byte determinism is what lets CI
regenerate the fixtures and diff them against the committed files.
"""

import numpy as np
import pytest

from compile import onnx_fixture as fx


def _varint(b, i):
    v = s = 0
    while True:
        x = b[i]
        i += 1
        v |= (x & 0x7F) << s
        if not x & 0x80:
            return v, i
        s += 7


def _walk(b):
    i = 0
    fields = {}
    while i < len(b):
        k, i = _varint(b, i)
        f, w = k >> 3, k & 7
        if w == 0:
            v, i = _varint(b, i)
        elif w == 2:
            n, i = _varint(b, i)
            v = b[i : i + n]
            i += n
        elif w == 5:
            v = b[i : i + 4]
            i += 4
        else:
            raise AssertionError(f"unexpected wire type {w}")
        fields.setdefault(f, []).append(v)
    return fields


@pytest.mark.parametrize("name", sorted(fx.FIXTURES))
def test_fixture_bytes_are_deterministic(name):
    a_model, a_x, a_y = fx.FIXTURES[name]()
    b_model, b_x, b_y = fx.FIXTURES[name]()
    assert a_model == b_model
    np.testing.assert_array_equal(a_x, b_x)
    np.testing.assert_array_equal(a_y, b_y)


@pytest.mark.parametrize("name", sorted(fx.FIXTURES))
def test_fixture_protobuf_framing(name):
    model_bytes, x, y = fx.FIXTURES[name]()
    m = _walk(model_bytes)
    assert 7 in m, "ModelProto must carry a GraphProto (field 7)"
    g = _walk(m[7][0])
    assert g[1], "graph must have nodes"
    assert len(g[11]) == 1, "exactly one data input"
    assert len(g[12]) == 1, "exactly one output"
    # Every node must parse and carry an op_type.
    for n in g[1]:
        node = _walk(n)
        assert node[4][0].decode(), "op_type"
    # Every initializer must carry FLOAT or INT64 raw data matching dims.
    for t in g[5]:
        tp = _walk(t)
        dims = tp.get(1, [])
        numel = int(np.prod(dims)) if dims else 1
        dtype = tp[2][0]
        width = 4 if dtype == 1 else 8
        assert len(tp[9][0]) == numel * width, tp[8][0]
    assert x.dtype == np.float32 and y.dtype == np.float32


def test_resnet8_has_batchnorm_to_fold():
    model_bytes, _, _ = fx.FIXTURES["resnet8"]()
    g = _walk(_walk(model_bytes)[7][0])
    ops = [_walk(n)[4][0].decode() for n in g[1]]
    assert ops.count("BatchNormalization") == 6
    assert ops.count("Conv") == 7
    assert ops.count("Add") == 2  # one identity skip, one projection skip
    assert "Gemm" in ops and "GlobalAveragePool" in ops and "MaxPool" in ops
